"""Fréchet inception distance (paper's metric, [11]).

The exact Fréchet formula is used:
    FID = |mu1 - mu2|^2 + tr(S1 + S2 - 2 (S1 S2)^{1/2})
with the matrix square root computed via the symmetric eigensystem of
sqrt(S1) S2 sqrt(S1).

The container is offline, so InceptionV3 weights are unavailable; the
feature extractor is a FIXED random convolutional network (seeded, 3
strided conv stages + global average pool). Random convolutional
features preserve distributional distances well enough for the paper's
*relative* comparisons (schedule vs schedule, proposed vs FedGAN), which
is what EXPERIMENTS.md validates. This substitution is recorded in
DESIGN.md.

IN-SCAN FID (PR 2 design note): the formula has a second, pure-jnp
implementation (`feature_stats_jnp` / `frechet_distance_jnp`, float32,
eigh-based like the numpy path) so a jittable fid_fn can run INSIDE the
fused driver's `lax.scan` via `lax.cond` on eval rounds. Per-round
`lax.cond` beats the old eval-boundary chunking because (a) the chunk
length no longer depends on `eval_every` alignment, so ONE compiled
chunk function serves the whole run instead of one compile per distinct
boundary-to-boundary length; (b) train state never leaves the device
between rounds, so buffer donation holds across the entire run rather
than being broken at every eval boundary; (c) `lax.cond` skips the eval
branch at runtime on non-eval rounds, so the amortized cost is
identical. Non-jittable fid_fns (e.g. the numpy path here) still work —
`core.engine` falls back to chunk-boundary host evaluation. The numpy
implementation stays the parity oracle (tests/test_fid_parity.py,
float64, agreement to ~1e-5 relative).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def make_feature_extractor(channels: int, *, feat_dim: int = 64,
                           seed: int = 42):
    """Fixed random conv feature extractor: images (b,H,W,C) -> (b, feat)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    widths = [16, 32, feat_dim]
    w0 = jax.random.normal(ks[0], (4, 4, channels, widths[0])) / 4.0
    w1 = jax.random.normal(ks[1], (4, 4, widths[0], widths[1])) / 8.0
    w2 = jax.random.normal(ks[2], (4, 4, widths[1], widths[2])) / 16.0

    @jax.jit
    def features(images):
        x = images.astype(jnp.float32)
        for w in (w0, w1, w2):
            x = jax.lax.conv_general_dilated(
                x, w, window_strides=(2, 2), padding=((1, 1), (1, 1)),
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jnp.tanh(x)
        return jnp.mean(x, axis=(1, 2))

    return features


def make_token_feature_extractor(vocab: int, *, feat_dim: int = 64,
                                 seed: int = 42):
    """Fixed random features for token/embedding sequences:
    (b, s) int tokens or (b, s, d) embeddings -> (b, feat)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    table = jax.random.normal(k1, (vocab, feat_dim)) * 0.3

    @jax.jit
    def features(x):
        if x.ndim == 2:  # token ids
            e = jnp.take(table, x, axis=0)
        else:
            proj = jax.random.normal(k2, (x.shape[-1], feat_dim)) * (
                x.shape[-1] ** -0.5)
            e = jnp.tanh(x.astype(jnp.float32) @ proj)
        # first + second order sequence statistics
        return jnp.concatenate([e.mean(1), jnp.tanh(e).std(1)], axis=-1)

    return features


def feature_stats(feats) -> tuple[np.ndarray, np.ndarray]:
    f = np.asarray(feats, dtype=np.float64)
    mu = f.mean(0)
    cov = np.cov(f, rowvar=False)
    return mu, np.atleast_2d(cov)


def _sqrtm_psd(mat: np.ndarray) -> np.ndarray:
    vals, vecs = np.linalg.eigh(mat)
    vals = np.clip(vals, 0.0, None)
    return (vecs * np.sqrt(vals)) @ vecs.T


def frechet_distance(mu1, cov1, mu2, cov2) -> float:
    s1_half = _sqrtm_psd(cov1)
    inner = _sqrtm_psd(s1_half @ cov2 @ s1_half)
    d2 = float(np.sum((mu1 - mu2) ** 2)
               + np.trace(cov1 + cov2 - 2.0 * inner))
    return max(d2, 0.0)


def fid_score(real_feats, fake_feats) -> float:
    mu1, c1 = feature_stats(real_feats)
    mu2, c2 = feature_stats(fake_feats)
    return frechet_distance(mu1, c1, mu2, c2)


# ---------------------------------------------------------------------------
# Pure-jnp twin — jittable, so FID can run inside the fused driver's scan
# ---------------------------------------------------------------------------

def feature_stats_jnp(feats):
    """jnp twin of `feature_stats`: (mu, cov) with np.cov's ddof=1."""
    f = jnp.asarray(feats, jnp.float32)
    mu = f.mean(0)
    d = f - mu
    cov = d.T @ d / jnp.float32(max(f.shape[0] - 1, 1))
    return mu, jnp.atleast_2d(cov)


def _sqrtm_psd_jnp(mat):
    vals, vecs = jnp.linalg.eigh(mat)
    vals = jnp.clip(vals, 0.0, None)
    return (vecs * jnp.sqrt(vals)) @ vecs.T


def frechet_distance_jnp(mu1, cov1, mu2, cov2):
    """jnp twin of `frechet_distance`; float32 scalar, jittable."""
    s1_half = _sqrtm_psd_jnp(jnp.asarray(cov1, jnp.float32))
    cov2 = jnp.asarray(cov2, jnp.float32)
    inner = _sqrtm_psd_jnp(s1_half @ cov2 @ s1_half)
    mu1 = jnp.asarray(mu1, jnp.float32)
    mu2 = jnp.asarray(mu2, jnp.float32)
    d2 = (jnp.sum((mu1 - mu2) ** 2)
          + jnp.trace(jnp.asarray(cov1, jnp.float32) + cov2 - 2.0 * inner))
    return jnp.maximum(d2, 0.0)


def fid_score_jnp(real_feats, fake_feats):
    """Jittable FID — use this (or any traceable fid_fn) to get in-scan
    evaluation from the fused driver; the numpy `fid_score` stays the
    float64 oracle."""
    mu1, c1 = feature_stats_jnp(real_feats)
    mu2, c2 = feature_stats_jnp(fake_feats)
    return frechet_distance_jnp(mu1, c1, mu2, c2)

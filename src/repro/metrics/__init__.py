from repro.metrics.fid import fid_score, feature_stats, make_feature_extractor

from repro.metrics.fid import (fid_score, feature_stats,
                               frechet_distance, make_feature_extractor,
                               fid_score_jnp, feature_stats_jnp,
                               frechet_distance_jnp)

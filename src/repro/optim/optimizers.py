"""Minimal functional optimizers (no external deps).

`Optimizer.update(grads, state, params)` returns `(updates, new_state)`
where `updates` should be ADDED to params to descend `grads`.
The paper's Algorithms 1 and 3 use plain mini-batch SGD; Adam/momentum
are provided for the practical variants and the LM examples.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_axpy(alpha, x, y):
    """y + alpha * x over pytrees."""
    return jax.tree.map(lambda xi, yi: yi + alpha * xi.astype(yi.dtype), x, y)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, updates):
    return tree_add(params, updates)


def sgd(lr: float) -> Optimizer:
    def init(_params):
        return {}

    def update(grads, state, _params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, _params=None):
        mu = jax.tree.map(lambda m, g: beta * m + g, state["mu"], grads)
        return jax.tree.map(lambda m: -lr * m, mu), {"mu": mu}

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, _params=None):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        updates = jax.tree.map(
            lambda mi, vi: -lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + eps), m, v)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def make_optimizer(name: str, lr: float, **kw) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, **kw)
    if name == "adam":
        return adam(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")

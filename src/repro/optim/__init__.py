from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adam,
    make_optimizer,
    apply_updates,
    tree_add,
    tree_axpy,
    global_norm,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

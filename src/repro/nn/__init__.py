"""Functional neural-network substrate.

Every module here is a pair of pure functions:

    init(key, cfg, ...) -> params (a pytree of jnp arrays)
    apply(params, inputs, ...) -> outputs

No classes carry state; parameters are explicit pytrees so the
distributed protocol (stacking, averaging, sharding) can manipulate them
directly.
"""
from repro.nn import initializers
from repro.nn.linear import linear_init, linear_apply
from repro.nn.tp import copy_to_tp, gather_from_tp, reduce_from_tp, tp_rank
from repro.nn.norms import (
    rmsnorm_init,
    rmsnorm_apply,
    layernorm_init,
    layernorm_apply,
    batchnorm_init,
    batchnorm_apply,
)
from repro.nn.embed import embedding_init, embedding_apply
from repro.nn.rope import rope_frequencies, apply_rope
from repro.nn.attention import attention_init, attention_apply, attention_kv
from repro.nn.mlp import mlp_init, mlp_apply
from repro.nn.moe import moe_init, moe_apply
from repro.nn.ssm import ssd_mixer_init, ssd_mixer_apply, ssd_scan_ref
from repro.nn.conv import (
    conv2d_init,
    conv2d_apply,
    conv_transpose2d_init,
    conv_transpose2d_apply,
)

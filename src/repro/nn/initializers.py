"""Parameter initializers (pure functions of a PRNG key and a shape)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def normal(key, shape, stddev: float = 0.02, dtype=jnp.float32):
    return stddev * jax.random.normal(key, shape, dtype=dtype)


def zeros(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype=dtype)


def ones(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype=dtype)


def lecun_normal(key, shape, fan_in: int | None = None, dtype=jnp.float32):
    """Variance-scaling init with fan-in taken from the first axis by default."""
    if fan_in is None:
        fan_in = shape[0] if len(shape) > 1 else 1
    stddev = 1.0 / math.sqrt(max(fan_in, 1))
    return stddev * jax.random.normal(key, shape, dtype=dtype)


def xavier_uniform(key, shape, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    fan_out = shape[-1]
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, minval=-limit, maxval=limit, dtype=dtype)


def dcgan_conv(key, shape, dtype=jnp.float32):
    """DCGAN paper init: N(0, 0.02) for all conv weights [Radford et al.]."""
    return 0.02 * jax.random.normal(key, shape, dtype=dtype)

"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

`ssd_scan_ref` is the pure-jnp chunked scan — the oracle for the Pallas
kernel in `repro.kernels.ssd_scan` and the CPU execution path.

Layout conventions:
  x   (b, s, h, p)   per-head inputs, p = head_dim
  dt  (b, s, h)      softplus-processed step sizes
  A   (h,)           negative per-head decay rates
  B,C (b, s, g, n)   per-group input/output projections, n = d_state
  state (b, h, n, p)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers
from repro.nn.norms import rmsnorm_init, rmsnorm_apply


# ---------------------------------------------------------------------------
# Chunked SSD scan (reference)
# ---------------------------------------------------------------------------

def _expand_groups(bc, n_heads):
    """(b, s, g, n) -> (b, s, h, n) by repeating groups across their heads."""
    g = bc.shape[2]
    assert n_heads % g == 0
    return jnp.repeat(bc, n_heads // g, axis=2)


def ssd_scan_ref(x, dt, A, B, C, *, chunk: int = 128,
                 initial_state=None, return_final_state: bool = False):
    """Chunked SSD scan: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T,
    y_t = C_t h_t. All math in float32."""
    in_dtype = x.dtype
    b, s, h, p = x.shape
    n = B.shape[-1]
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B = _expand_groups(B.astype(jnp.float32), h)
    C = _expand_groups(C.astype(jnp.float32), h)

    chunk = min(chunk, s)
    orig_s = s
    if s % chunk:
        # pad with dt=0 steps: decay=exp(0)=1, no input — state is unchanged
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk

    def to_chunks(t):
        return t.reshape((b, nc, chunk) + t.shape[2:])

    xc, dtc, Bc, Cc = map(to_chunks, (x, dt, B, C))
    causal = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))

    if initial_state is None:
        s0 = jnp.zeros((b, h, n, p), dtype=jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def chunk_step(state, inp):
        """One chunk: quadratic within-chunk term + state recurrence.

        Scanning over chunks keeps the (l, l) score block O(1) in live
        memory — the long-sequence prefill path depends on this.
        """
        xk, dtk, Bk, Ck = inp                          # (b, l, ...)
        xdt = xk * dtk[..., None]                      # (b, l, h, p)
        a = dtk * A.astype(jnp.float32)                # (b, l, h)
        cs = jnp.cumsum(a, axis=1)                     # (b, l, h)
        seg = cs[:, :, None, :] - cs[:, None, :, :]    # (b, l, l, h)
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        y_diag = jnp.einsum("blhn,bshn,blsh,bshp->blhp", Ck, Bk, L, xdt)
        # carried-state contribution
        y_off = jnp.einsum("blhn,bhnp,blh->blhp", Ck, state, jnp.exp(cs))
        # state update
        decay_states = jnp.exp(cs[:, -1:, :] - cs)     # (b, l, h)
        total = jnp.exp(cs[:, -1, :])                  # (b, h)
        new_state = (total[..., None, None] * state
                     + jnp.einsum("bshn,bsh,bshp->bhnp", Bk, decay_states,
                                  xdt))
        return new_state, (y_diag + y_off)

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xc, dtc, Bc, Cc))
    final_state, ys = jax.lax.scan(chunk_step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)[:, :orig_s]
    y = y.astype(in_dtype)
    if return_final_state:
        return y, final_state
    return y


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token recurrent update. x: (b, h, p); B, C: (b, g, n);
    state: (b, h, n, p). Returns (y, new_state)."""
    h = x.shape[1]
    Bh = _expand_groups(B.astype(jnp.float32)[:, None], h)[:, 0]  # (b, h, n)
    Ch = _expand_groups(C.astype(jnp.float32)[:, None], h)[:, 0]
    dt = dt.astype(jnp.float32)
    decay = jnp.exp(dt * A.astype(jnp.float32))        # (b, h)
    xdt = x.astype(jnp.float32) * dt[..., None]
    new_state = (decay[..., None, None] * state.astype(jnp.float32)
                 + jnp.einsum("bhn,bhp->bhnp", Bh, xdt))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# Full Mamba-2 mixer (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------

def ssd_mixer_init(key, d_model: int, *, d_state: int, head_dim: int = 64,
                   expand: int = 2, n_groups: int = 1, d_conv: int = 4,
                   dtype=jnp.float32):
    d_inner = expand * d_model
    assert d_inner % head_dim == 0
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_groups * d_state
    d_in_proj = 2 * d_inner + 2 * n_groups * d_state + n_heads
    ks = jax.random.split(key, 5)
    params = {
        "in_proj": initializers.lecun_normal(ks[0], (d_model, d_in_proj), dtype=dtype),
        "conv_w": initializers.lecun_normal(ks[1], (d_conv, conv_dim),
                                            fan_in=d_conv, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (n_heads,),
                                       minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
        )).astype(dtype),
        "norm": rmsnorm_init(d_inner, dtype=dtype),
        "out_proj": initializers.lecun_normal(ks[3], (d_inner, d_model),
                                              fan_in=d_inner, dtype=dtype),
    }
    return params


def _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads):
    sizes = [d_inner, d_inner, n_groups * d_state, n_groups * d_state, n_heads]
    idx, acc = [], 0
    for sz in sizes[:-1]:
        acc += sz
        idx.append(acc)
    z, xr, B, C, dt = jnp.split(zxbcdt, idx, axis=-1)
    return z, xr, B, C, dt


def _causal_conv(seq, w, b):
    """Depthwise causal conv. seq: (b, s, c); w: (k, c)."""
    k = w.shape[0]
    pad = jnp.pad(seq, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + seq.shape[1], :] * w[i].astype(seq.dtype)
              for i in range(k))
    return out + b.astype(seq.dtype)


def ssd_mixer_apply(params, x, *, d_state: int, head_dim: int = 64,
                    expand: int = 2, n_groups: int = 1, chunk: int = 128,
                    state: Optional[dict] = None, token_mask=None,
                    scan_impl=None, return_state: bool = False):
    """Mamba-2 mixer. x: (b, s, d).

    state: None for training/prefill-from-scratch. For decode pass
    {"ssm": (b,h,n,p), "conv": (b, k-1, conv_dim)}; s = 1 is single-token
    decode, s > 1 is a state-carrying chunk (chunked prefill continuation).
    token_mask: optional (b, s) bool — masked tokens are EXACT state
    no-ops (dt forced to 0 so decay=exp(0)=1 with zero input, and the
    conv carry window advances only past valid tokens). The valid tokens
    must be a contiguous prefix of the chunk. This is what lets one
    jitted serving step carry inactive slots / padded chunk tails
    without touching their state.
    Returns y, or (y, new_state) when state is given.
    scan_impl: optional override for the chunked scan (Pallas kernel hook).
    """
    b, s, d_model = x.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * n_groups * d_state

    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xr, B, C, dt_raw = _split_proj(zxbcdt, d_inner, n_groups, d_state, n_heads)
    conv_in = jnp.concatenate([xr, B, C], axis=-1)     # (b, s, conv_dim)

    if state is not None:
        kw = params["conv_w"].shape[0]
        window = jnp.concatenate([state["conv"], conv_in], axis=1)
        if token_mask is None:
            # carry = last kw-1 rows (all s tokens advance the window)
            new_conv_state = window[:, s:, :]
        else:
            # valid tokens occupy window rows [kw-1, kw-1+n_valid), so the
            # carry is rows [n_valid, n_valid+kw-1); n_valid=0 reproduces
            # the old conv state bitwise (inactive decode slot)
            n_valid = jnp.sum(token_mask.astype(jnp.int32), axis=1)
            idx = n_valid[:, None] + jnp.arange(kw - 1, dtype=jnp.int32)[None]
            new_conv_state = jnp.take_along_axis(window, idx[:, :, None],
                                                 axis=1)
        # causal conv continued across the carried window; for
        # state["conv"] == zeros this matches _causal_conv bitwise
        conv_out = sum(
            window[:, i:i + s, :] * params["conv_w"][i].astype(x.dtype)
            for i in range(kw)) + params["conv_b"].astype(x.dtype)
    else:
        new_conv_state = None
        conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    conv_out = jax.nn.silu(conv_out)

    xr, B, C = jnp.split(conv_out,
                         [d_inner, d_inner + n_groups * d_state], axis=-1)
    xh = xr.reshape(b, s, n_heads, head_dim)
    Bh = B.reshape(b, s, n_groups, d_state)
    Ch = C.reshape(b, s, n_groups, d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    if token_mask is not None:
        dt = dt * token_mask.astype(dt.dtype)[:, :, None]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    if state is not None and s == 1:
        y1, new_ssm = ssd_decode_step(state["ssm"], xh[:, 0], dt[:, 0],
                                      A, Bh[:, 0], Ch[:, 0])
        y = y1[:, None]
        new_state = {"ssm": new_ssm, "conv": new_conv_state}
    elif state is not None:
        # state-carrying chunk: always the reference scan — kernel impls
        # need not support initial_state, and serving chunks are short
        y, new_ssm = ssd_scan_ref(xh, dt, A, Bh, Ch, chunk=chunk,
                                  initial_state=state["ssm"],
                                  return_final_state=True)
        new_state = {"ssm": new_ssm, "conv": new_conv_state}
    elif return_state:
        # prefill: emit the decode state (SSM carry + conv tail window)
        scan = scan_impl if scan_impl is not None else ssd_scan_ref
        y, final_ssm = scan(xh, dt, A, Bh, Ch, chunk=chunk,
                            return_final_state=True)
        k = params["conv_w"].shape[0]
        new_state = {"ssm": final_ssm, "conv": conv_in[:, s - (k - 1):, :]}
    else:
        scan = scan_impl if scan_impl is not None else ssd_scan_ref
        y = scan(xh, dt, A, Bh, Ch, chunk=chunk)
        new_state = None

    y = (y.astype(jnp.float32)
         + params["D"].astype(jnp.float32)[None, None, :, None]
         * xh.astype(jnp.float32))
    y = y.reshape(b, s, d_inner)
    y = rmsnorm_apply(params["norm"], y.astype(x.dtype) * jax.nn.silu(z))
    y = y @ params["out_proj"].astype(y.dtype)
    if state is not None or return_state:
        return y, new_state
    return y

"""Normalization layers: RMSNorm (LLM backbones), LayerNorm (whisper),
BatchNorm (DCGAN — batch-statistics mode, as used during GAN training)."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm_apply(params, x, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * (var + eps) ** -0.5
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm_apply(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def batchnorm_init(c: int, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype=dtype), "bias": jnp.zeros((c,), dtype=dtype)}


def batchnorm_apply(params, x, *, eps: float = 1e-5):
    """BatchNorm over (N, H, W) for NHWC inputs using batch statistics.

    GAN training always normalizes with the current batch (DCGAN setup);
    we deliberately carry no running statistics — generation-time batches
    are normalized the same way, matching the reference DCGAN recipe.
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    y = (x - mean) * (var + eps) ** -0.5
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)

"""Dense projection."""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.nn import initializers


def linear_init(key, d_in: int, d_out: int, *, use_bias: bool = True,
                init=initializers.lecun_normal, dtype=jnp.float32):
    kw, _ = jax.random.split(key)
    params = {"w": init(kw, (d_in, d_out), dtype=dtype)}
    if use_bias:
        params["b"] = jnp.zeros((d_out,), dtype=dtype)
    return params


def linear_apply(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y

"""Dense projection, with Megatron column/row-parallel modes.

`tp_mode` selects how a TP-sharded weight shard participates inside a
shard_map slice (see nn/tp.py for the collective pairs):

  "column" — w shard = a slice of the OUTPUT dim. Input is replicated
      (copy_to_tp pins the backward dx all-reduce); output stays
      sharded unless gather_output=True all-gathers it back.
  "row"    — w shard = a slice of the INPUT dim. Input arrives sharded
      (the preceding column layer's output); the partial products are
      psum'd (reduce_from_tp) and the replicated bias is added AFTER
      the reduction, exactly matching the unsharded matmul.

With tp_axis=None both modes degrade to the plain dense projection.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

from repro.nn import initializers
from repro.nn.tp import copy_to_tp, gather_from_tp, reduce_from_tp


def linear_init(key, d_in: int, d_out: int, *, use_bias: bool = True,
                init=initializers.lecun_normal, dtype=jnp.float32):
    kw, _ = jax.random.split(key)
    params = {"w": init(kw, (d_in, d_out), dtype=dtype)}
    if use_bias:
        params["b"] = jnp.zeros((d_out,), dtype=dtype)
    return params


def linear_apply(params, x, *, tp_axis=None, tp_mode=None,
                 gather_output: bool = False):
    if tp_axis is not None and tp_mode == "row":
        y = reduce_from_tp(x @ params["w"].astype(x.dtype), tp_axis)
        if "b" in params:
            y = y + params["b"].astype(x.dtype)
        return y
    if tp_axis is not None and tp_mode == "column":
        y = copy_to_tp(x, tp_axis) @ params["w"].astype(x.dtype)
        if "b" in params:
            y = y + params["b"].astype(x.dtype)   # bias shard, output-dim
        if gather_output:
            y = gather_from_tp(y, tp_axis, dim=-1)
        return y
    if tp_axis is not None:
        raise ValueError(f"tp_mode must be 'column' or 'row' with a "
                         f"tp_axis (got {tp_mode!r})")
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y

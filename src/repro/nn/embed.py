"""Token embedding table."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import initializers


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": initializers.normal(key, (vocab, d), stddev=0.02, dtype=dtype)}


def embedding_apply(params, token_ids, *, dtype=None):
    table = params["table"]
    if dtype is not None:
        table = table.astype(dtype)
    return jnp.take(table, token_ids, axis=0)


def embedding_attend(params, x):
    """Tied readout: project hidden states onto the embedding table."""
    return x @ params["table"].astype(x.dtype).T

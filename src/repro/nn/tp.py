"""Megatron-style tensor-parallel collectives for shard_map slices.

Inside a mesh slice the `model` axis is MANUAL (jax.shard_map), so the
classic Megatron f/g operators are expressed as custom-vjp pairs over
`lax.psum` / `lax.all_gather` instead of GSPMD sharding constraints:

  `copy_to_tp`     — Megatron "f": identity forward, psum backward.
      Marks a REPLICATED activation entering a column-parallel matmul;
      the backward all-reduce sums each rank's partial dx.
  `reduce_from_tp` — Megatron "g": psum forward, identity backward.
      Closes a row-parallel matmul: the forward all-reduce sums the
      partial products over the sharded contraction dim, and the
      (replicated) cotangent flows straight through.
  `gather_from_tp` — all_gather forward, local-slice backward.
      Rematerializes a full activation from a column-parallel output
      when the next op needs the whole feature dim.

Why custom_vjp instead of differentiating raw `lax.psum`: under
`check_vma/check_rep=False` JAX transposes collectives mechanically,
which silently DROPS the cross-rank dx sum of a column-parallel matmul
(each rank's local AD only sees its own partial product). The pairs
below pin the collective placement on both sides of the tape.

All three are identity when `axis` is None, so TP-aware model code runs
unchanged outside shard_map (tp=1, the host oracle, the stacked layout).
"""
from __future__ import annotations

import functools

import jax


# custom_vjp calling convention: fwd takes the PRIMAL argument order
# (nondiff args in place); bwd takes the nondiff args FIRST, then
# residuals, then the cotangent.

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _copy_to_tp(x, axis):
    return x


def _copy_fwd(x, axis):
    return x, None


def _copy_bwd(axis, _res, g):
    return (jax.lax.psum(g, axis),)


_copy_to_tp.defvjp(_copy_fwd, _copy_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _reduce_from_tp(x, axis):
    return jax.lax.psum(x, axis)


def _reduce_fwd(x, axis):
    return jax.lax.psum(x, axis), None


def _reduce_bwd(axis, _res, g):
    return (g,)


_reduce_from_tp.defvjp(_reduce_fwd, _reduce_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _gather_from_tp(x, axis, dim):
    return jax.lax.all_gather(x, axis, axis=dim, tiled=True)


def _gather_fwd(x, axis, dim):
    return _gather_from_tp(x, axis, dim), x.shape[dim]


def _gather_bwd(axis, dim, local, g):
    rank = jax.lax.axis_index(axis)
    return (jax.lax.dynamic_slice_in_dim(g, rank * local, local, axis=dim),)


_gather_from_tp.defvjp(_gather_fwd, _gather_bwd)


def copy_to_tp(x, axis):
    """Identity fwd / psum bwd (column-parallel input). No-op axis=None."""
    return x if axis is None else _copy_to_tp(x, axis)


def reduce_from_tp(x, axis):
    """psum fwd / identity bwd (row-parallel output). No-op axis=None."""
    return x if axis is None else _reduce_from_tp(x, axis)


def gather_from_tp(x, axis, dim=-1):
    """all_gather fwd / own-slice bwd (column-parallel output gather).
    No-op when axis is None."""
    return x if axis is None else _gather_from_tp(x, axis, dim % x.ndim)


def tp_rank(axis):
    """This slice's index on the model axis (0 when axis is None)."""
    return 0 if axis is None else jax.lax.axis_index(axis)

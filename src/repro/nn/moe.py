"""Mixture-of-experts feed-forward with top-k routing.

Baseline dispatch is the GShard/Switch formulation: tokens are split into
groups; within a group, a one-hot dispatch tensor (g, t, E, C) routes at
most C tokens to each expert via einsum. Under GSPMD with experts sharded
on the `model` axis this lowers to the canonical all-to-all pattern.

A sort-based (gather/scatter) dispatch lives alongside as the
memory-lean variant — see `moe_apply(..., dispatch="sort")`; the §Perf
hillclimb compares the two.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers
from repro.nn.mlp import mlp_init, mlp_apply

# max T*top_k for the exact (worst-case-buffer) dropless sort dispatch
_DROPLESS_EXACT_LIMIT = 4096

# optional sharding pin for dispatched expert tensors (set by
# launch/variants): "replicated" keeps expert_in/out unsharded within the
# device group so the expert matmuls contract the TP dim with one partial
# -sum all-reduce instead of GSPMD re-gathering dispatch tensors.
CONSTRAIN_DISPATCH = None


def _pin_dispatch(t):
    if CONSTRAIN_DISPATCH != "replicated":
        return t
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(t, P(*([None] * t.ndim)))


def moe_init(key, d_model: int, d_ff: int, n_experts: int, *, dtype=jnp.float32):
    k_router, k_experts = jax.random.split(key)
    expert_keys = jax.random.split(k_experts, n_experts)
    experts = jax.vmap(
        lambda k: mlp_init(k, d_model, d_ff, gated=True, dtype=dtype))(expert_keys)
    return {
        "router": initializers.lecun_normal(k_router, (d_model, n_experts), dtype=dtype),
        "experts": experts,  # leaves have leading (E,) axis
    }


def _route(params, x2d, n_experts: int, top_k: int):
    """Router logits -> (gates, expert one-hots, aux loss terms)."""
    logits = x2d.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)          # (T, K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    # Load-balance loss (Switch): E * sum_e mean(frac_tokens_e) * mean(prob_e)
    chosen = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.float32)  # (T,K,E)
    frac = chosen.sum(1).mean(0)                                 # (E,)
    aux = n_experts * jnp.sum(frac * probs.mean(0))
    return probs, gate_vals, expert_idx, chosen, aux


def moe_apply(params, x, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, group_size: int = 2048,
              dispatch: str = "einsum", dropless: bool = False):
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar).

    dropless=True routes every (token, slot) pair exactly (sort dispatch
    with full per-expert capacity) — the serving-decode path, where
    capacity drops would change results batch-dependently. Exact
    worst-case buffers are (E, T*top_k, d), so this is only used for
    small token counts (decode steps); large-T serving (prefill) falls
    back to the grouped capacity dispatch with a generous factor, which
    shards cleanly over the token axis.
    """
    if dropless:
        if x.shape[0] * x.shape[1] * top_k <= _DROPLESS_EXACT_LIMIT:
            dispatch = "sort"
        else:
            dispatch = "einsum"
            capacity_factor = max(capacity_factor, 2.0)
            dropless = False
    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    t_total = b * s
    gs = min(group_size, t_total)
    # pad so groups divide evenly
    n_groups = math.ceil(t_total / gs)
    pad = n_groups * gs - t_total
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    probs, gates, expert_idx, chosen, aux = _route(params, x2d, n_experts, top_k)
    capacity = max(1, int(gs * capacity_factor * top_k / n_experts))
    capacity = min(capacity, gs)

    if dispatch == "einsum":
        y2d = _dispatch_einsum(params, x2d, gates, chosen, n_groups, gs,
                               n_experts, top_k, capacity)
    elif dispatch == "sort":
        cap_total = x2d.shape[0] * top_k if dropless else capacity * n_groups
        y2d = _dispatch_sort(params, x2d, gates, expert_idx,
                             n_experts, top_k, cap_total)
    else:
        raise ValueError(f"unknown dispatch {dispatch!r}")

    if pad:
        y2d = y2d[:t_total]
    return y2d.reshape(b, s, d).astype(x.dtype), aux


def _dispatch_einsum(params, x2d, gates, chosen, n_groups, gs,
                     n_experts, top_k, capacity):
    d = x2d.shape[-1]
    xg = x2d.reshape(n_groups, gs, d)
    chosen_g = chosen.reshape(n_groups, gs, top_k, n_experts)
    gates_g = gates.reshape(n_groups, gs, top_k)

    # Position of each (token, slot) within its expert queue, slot-major so
    # first-choice assignments win capacity, as in GShard.
    # cumulative count over (slot, token) ordering:
    flat = jnp.swapaxes(chosen_g, 1, 2).reshape(n_groups, top_k * gs, n_experts)
    pos_flat = jnp.cumsum(flat, axis=1) - flat                  # (g, K*t, E)
    pos = jnp.swapaxes(pos_flat.reshape(n_groups, top_k, gs, n_experts), 1, 2)
    keep = (pos < capacity) & (chosen_g > 0)                    # (g, t, K, E)
    pos = jnp.sum(pos * chosen_g, axis=-1)                      # (g, t, K)

    pos_oh = jax.nn.one_hot(jnp.where(keep.any(-1), pos, capacity),
                            capacity, dtype=x2d.dtype)          # (g, t, K, C)
    disp = jnp.einsum("gtke,gtkc->gtec", chosen_g.astype(x2d.dtype) *
                      keep.astype(x2d.dtype), pos_oh)           # (g, t, E, C)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec",
                      chosen_g.astype(jnp.float32) * keep.astype(jnp.float32),
                      pos_oh.astype(jnp.float32), gates_g.astype(jnp.float32))

    expert_in = _pin_dispatch(
        jnp.einsum("gtec,gtd->egcd", disp, xg))                  # (E, g, C, d)
    expert_out = _pin_dispatch(
        jax.vmap(mlp_apply)(params["experts"], expert_in))
    y = jnp.einsum("gtec,egcd->gtd", comb.astype(expert_out.dtype), expert_out)
    return y.reshape(n_groups * gs, d)


def _dispatch_sort(params, x2d, gates, expert_idx, n_experts, top_k, capacity_total):
    """Memory-lean dispatch: sort (token, slot) pairs by expert, gather a
    fixed per-expert buffer, run experts, scatter-add back with gates."""
    t = x2d.shape[0]
    flat_expert = expert_idx.reshape(-1)                        # (T*K,)
    flat_gate = gates.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(t), top_k)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # position within expert = rank - start_of_expert
    counts = jnp.bincount(se, length=n_experts)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(se.shape[0])
    pos_in_e = rank - starts[se]
    cap = min(capacity_total, se.shape[0])
    keep = pos_in_e < cap
    # scatter (expert, pos) -> source row; dropped entries park at a dummy row
    buf_idx = jnp.where(keep, se * cap + pos_in_e, n_experts * cap)
    src = jnp.zeros((n_experts * cap + 1,), dtype=jnp.int32).at[buf_idx].set(
        st.astype(jnp.int32), mode="drop")
    filled = jnp.zeros((n_experts * cap + 1,), dtype=bool).at[buf_idx].set(
        keep, mode="drop")
    expert_in = x2d[src[:-1]].reshape(n_experts, cap, -1)
    expert_in = expert_in * filled[:-1].reshape(n_experts, cap, 1).astype(x2d.dtype)
    expert_out = jax.vmap(mlp_apply)(params["experts"], expert_in)
    flat_out = expert_out.reshape(n_experts * cap, -1)
    contrib = jnp.where(keep, sg, 0.0)[:, None].astype(flat_out.dtype)
    safe_buf = jnp.minimum(buf_idx, n_experts * cap - 1)
    gathered = flat_out[safe_buf] * contrib
    y = jnp.zeros_like(x2d).at[st].add(gathered.astype(x2d.dtype), mode="drop")
    return y

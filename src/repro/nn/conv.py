"""2-D convolutions for the paper's DCGAN model (NHWC layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import initializers


def conv2d_init(key, c_in: int, c_out: int, kernel: int, *, use_bias: bool = False,
                dtype=jnp.float32):
    params = {"w": initializers.dcgan_conv(
        key, (kernel, kernel, c_in, c_out), dtype=dtype)}
    if use_bias:
        params["b"] = jnp.zeros((c_out,), dtype=dtype)
    return params


def conv2d_apply(params, x, *, stride: int = 2, padding: int = 1):
    y = jax.lax.conv_general_dilated(
        x, params["w"].astype(x.dtype),
        window_strides=(stride, stride),
        padding=((padding, padding), (padding, padding)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def conv_transpose2d_init(key, c_in: int, c_out: int, kernel: int, *,
                          use_bias: bool = False, dtype=jnp.float32):
    params = {"w": initializers.dcgan_conv(
        key, (kernel, kernel, c_in, c_out), dtype=dtype)}  # HWIO
    if use_bias:
        params["b"] = jnp.zeros((c_out,), dtype=dtype)
    return params


def conv_transpose2d_apply(params, x, *, stride: int = 2, padding: int = 1):
    """Fractionally-strided conv (PyTorch ConvTranspose2d semantics):
    out = (in - 1) * stride - 2 * padding + kernel."""
    kernel = params["w"].shape[0]
    y = jax.lax.conv_transpose(
        x, params["w"].astype(x.dtype),
        strides=(stride, stride),
        padding=((kernel - 1 - padding, kernel - 1 - padding),) * 2,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y

"""Grouped-query attention with RoPE, qk-norm, sliding windows,
cross-attention, and KV-cache support.

One implementation serves every assigned architecture:
  - full causal attention            (granite, qwen3, minitron, llama-vision)
  - sliding-window causal attention  (mixtral SWA, gemma3 local layers)
  - bidirectional attention          (whisper encoder)
  - cross attention                  (whisper decoder, llama-vision image layers)
  - single-token decode against a (possibly sequence-sharded) KV cache
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import initializers
from repro.nn.norms import rmsnorm_init, rmsnorm_apply
from repro.nn.rope import apply_rope
from repro.nn.flash_ref import flash_attention_ref

NEG_INF = -1e30
# above this (s_q * s_k) product, non-decode attention goes through the
# blockwise flash path (the naive path materializes b*h*s*t f32 scores)
_FLASH_THRESHOLD = 512 * 512 + 1
# optional mesh axis to pin flash q/k/v heads to (set by launch/steps.py);
# makes the whole flash scan tensor-parallel-local over heads so GSPMD
# inserts no per-block reshards. None = let GSPMD decide.
FLASH_HEAD_AXIS = None


def _pin_heads(t):
    """t: (b, H, s, hd) — constrain H onto FLASH_HEAD_AXIS if set."""
    if FLASH_HEAD_AXIS is None:
        return t
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        t, P(None, FLASH_HEAD_AXIS, None, None))


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: Optional[int] = None, *, qk_norm: bool = False,
                   use_bias: bool = False, kv_d_model: Optional[int] = None,
                   fuse_qkv: bool = False, dtype=jnp.float32):
    if head_dim is None:
        head_dim = d_model // n_heads
    if kv_d_model is None:
        kv_d_model = d_model
    assert n_heads % n_kv_heads == 0, "GQA requires n_heads % n_kv_heads == 0"
    ks = jax.random.split(key, 4)
    if fuse_qkv and kv_d_model == d_model:
        # one fused projection: one matmul fwd, ONE dx all-reduce bwd
        # (vs three) under tensor parallelism — §Perf iteration.
        params = {
            "wqkv": initializers.lecun_normal(
                ks[0], (d_model, (n_heads + 2 * n_kv_heads) * head_dim),
                dtype=dtype),
            "wo": initializers.lecun_normal(
                ks[3], (n_heads * head_dim, d_model),
                fan_in=n_heads * head_dim, dtype=dtype),
        }
        if use_bias:
            params["bqkv"] = jnp.zeros(
                ((n_heads + 2 * n_kv_heads) * head_dim,), dtype=dtype)
            params["bo"] = jnp.zeros((d_model,), dtype=dtype)
        if qk_norm:
            params["q_norm"] = rmsnorm_init(head_dim, dtype=dtype)
            params["k_norm"] = rmsnorm_init(head_dim, dtype=dtype)
        return params
    params = {
        "wq": initializers.lecun_normal(ks[0], (d_model, n_heads * head_dim), dtype=dtype),
        "wk": initializers.lecun_normal(ks[1], (kv_d_model, n_kv_heads * head_dim), dtype=dtype),
        "wv": initializers.lecun_normal(ks[2], (kv_d_model, n_kv_heads * head_dim), dtype=dtype),
        "wo": initializers.lecun_normal(
            ks[3], (n_heads * head_dim, d_model), fan_in=n_heads * head_dim, dtype=dtype),
    }
    if use_bias:
        params["bq"] = jnp.zeros((n_heads * head_dim,), dtype=dtype)
        params["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype=dtype)
        params["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype=dtype)
        params["bo"] = jnp.zeros((d_model,), dtype=dtype)
    if qk_norm:
        params["q_norm"] = rmsnorm_init(head_dim, dtype=dtype)
        params["k_norm"] = rmsnorm_init(head_dim, dtype=dtype)
    return params


def _project(params, name, x, n_heads, head_dim):
    y = x @ params[f"w{name}"].astype(x.dtype)
    bias = params.get(f"b{name}")
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y.reshape(x.shape[:-1] + (n_heads, head_dim))


def build_mask(q_positions, k_positions, *, causal: bool,
               window: Optional[int], k_valid=None):
    """Additive attention bias (..., q, k) in float32.

    q_positions: (..., q) int32 absolute positions of queries.
    k_positions: (..., k) int32 absolute positions of keys.
    window: if set, keys older than `window` positions are masked
            (sliding-window attention; window includes the current token).
    k_valid: optional (..., k) bool marking populated cache slots.
    """
    qp = q_positions[..., :, None]
    kp = k_positions[..., None, :]
    allowed = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        allowed &= kp <= qp
    if window is not None:
        allowed &= kp > qp - window
    if k_valid is not None:
        allowed &= k_valid[..., None, :]
    return jnp.where(allowed, 0.0, NEG_INF).astype(jnp.float32)


def attention_kv(params, kv_x, *, n_kv_heads: int, qk_norm: bool = False):
    """Project cross-attention keys/values once (decode-cache fill).

    Matches the k/v the cross prefill path produces (no RoPE — cross
    attention never rotates), so a serving engine can populate the
    per-slot cross caches at admission without a full prefill pass.
    """
    head_dim = params["wk"].shape[1] // n_kv_heads
    k = _project(params, "k", kv_x, n_kv_heads, head_dim)
    v = _project(params, "v", kv_x, n_kv_heads, head_dim)
    if qk_norm:
        k = rmsnorm_apply(params["k_norm"], k)
    return {"k": k, "v": v}


def _dedup_ring_slots(slots, positions, mask):
    """Last-write-wins for scatter inserts into a ring buffer: when two
    tokens of one chunk map to the same ring slot (chunk longer than the
    window), keep only the latest position per slot."""
    later_same = (slots[:, :, None] == slots[:, None, :]) \
        & mask[:, None, :] \
        & (positions[:, None, :] > positions[:, :, None])
    return mask & ~later_same.any(axis=-1)


def attention_apply(params, x, *, n_heads: int, n_kv_heads: int,
                    inv_freq=None, q_positions=None, kv_positions=None,
                    causal: bool = True, window: Optional[int] = None,
                    kv_x=None, cache=None, cache_index=None,
                    cache_write_mask=None, paged_table=None,
                    qk_norm: bool = False, extra_mask=None,
                    return_kv: bool = False, kv_override=None,
                    flash_repeat_kv: bool = False):
    """Attention forward.

    x:  (b, s, d) queries source.
    kv_x: optional (b, t, d_kv) for cross attention (keys/values source);
          defaults to x (self attention).
    cache: optional dict {"k": (b, L, kv, hd), "v": ..., "pos": (b, L) int32
           absolute positions, "valid": (b, L) bool}. When given with
           cache_index, the fresh k/v are inserted at that slot index
           (decode), and attention runs over the whole cache.

    Serving (any-position) cache conventions — `cache` given with
    `cache_index=None`:
      * dense scatter insert: each token's cache slot is its absolute
        position `q_positions[b, s]` (mod L for sliding windows), so a
        batch can decode at arbitrary per-slot positions, and a chunk
        of s > 1 prompt tokens lands at its positions in one call.
        `cache_write_mask` (b, s) drops writes (inactive slots, padded
        chunk tail) — dropped tokens leave the cache bitwise unchanged.
      * paged insert (`paged_table` (b, max_blocks) given): cache leaves
        are a shared BLOCK POOL {"k": (n_blocks, bs, kv, hd), ...,
        "pos"/"valid": (n_blocks, bs)}; token positions map through the
        slot's block table into pool rows, and attention runs over the
        table-gathered per-slot view. Block 0 is the never-written null
        block that padding table entries point at.
    Returns y (and updated cache / fresh kv when requested).
    """
    b, s, _ = x.shape
    fused_proj = "wqkv" in params
    head_dim = (params["wqkv"].shape[1] // (n_heads + 2 * n_kv_heads)
                if fused_proj else params["wq"].shape[1] // n_heads)
    kv_src = x if kv_x is None else kv_x

    if fused_proj:
        assert kv_x is None, "fused qkv is self-attention only"
        fused = x @ params["wqkv"].astype(x.dtype)
        if "bqkv" in params:
            fused = fused + params["bqkv"].astype(x.dtype)
        nq = n_heads * head_dim
        nkv = n_kv_heads * head_dim
        q = fused[..., :nq].reshape(x.shape[:-1] + (n_heads, head_dim))
        k = fused[..., nq:nq + nkv].reshape(
            x.shape[:-1] + (n_kv_heads, head_dim))
        v = fused[..., nq + nkv:].reshape(
            x.shape[:-1] + (n_kv_heads, head_dim))
        if kv_override is not None:
            k = kv_override["k"].astype(x.dtype)
            v = kv_override["v"].astype(x.dtype)
            if kv_positions is None and "pos" in kv_override:
                kv_positions = kv_override["pos"]
    else:
        q = _project(params, "q", x, n_heads, head_dim)
        if kv_override is not None:
            # Pre-projected keys/values (e.g. cross-attention decode against
            # a prefilled encoder cache) — skip the k/v projections entirely.
            k = kv_override["k"].astype(x.dtype)
            v = kv_override["v"].astype(x.dtype)
            if kv_positions is None and "pos" in kv_override:
                kv_positions = kv_override["pos"]
        else:
            k = _project(params, "k", kv_src, n_kv_heads, head_dim)
            v = _project(params, "v", kv_src, n_kv_heads, head_dim)

    if qk_norm:
        q = rmsnorm_apply(params["q_norm"], q)
        if kv_override is None:
            k = rmsnorm_apply(params["k_norm"], k)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if kv_positions is None:
        if kv_override is not None:
            # pre-projected k/v (cross-attn decode): positions index the
            # override's own length, not the query chunk's
            kv_positions = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32), (b, k.shape[1]))
        elif kv_x is None:
            kv_positions = q_positions
        else:
            kv_positions = jnp.broadcast_to(
                jnp.arange(kv_src.shape[1], dtype=jnp.int32),
                (b, kv_src.shape[1]))

    if inv_freq is not None:
        q = apply_rope(q, q_positions, inv_freq)
        if kv_override is None:  # overridden k already carries its rotation
            k = apply_rope(k, kv_positions, inv_freq)

    k_valid = None
    if cache is not None and paged_table is not None:
        # Paged insert: positions map through the slot's block table into
        # rows of the shared pool; masked/overflow writes are routed to an
        # out-of-range flat index and dropped (NEVER a negative index —
        # negative scatter indices wrap in JAX).
        n_blocks, blk = cache["k"].shape[0], cache["k"].shape[1]
        max_blocks = paged_table.shape[1]
        pos = kv_positions.astype(jnp.int32)
        blk_idx = jnp.clip(pos // blk, 0, max_blocks - 1)
        block_ids = jnp.take_along_axis(paged_table, blk_idx, axis=1)  # (b, s)
        flat = block_ids * blk + pos % blk
        mask = (cache_write_mask if cache_write_mask is not None
                else jnp.ones((b, s), dtype=bool))
        # block 0 is the reserved null block: padding table entries point
        # at it and it must never be written
        mask = mask & (block_ids > 0)
        flat = jnp.where(mask, flat, n_blocks * blk)
        fshape = (n_blocks * blk,)
        k_pool = cache["k"].reshape(fshape + cache["k"].shape[2:])
        v_pool = cache["v"].reshape(fshape + cache["v"].shape[2:])
        pos_pool = cache["pos"].reshape(fshape)
        val_pool = cache["valid"].reshape(fshape)
        k_pool = k_pool.at[flat].set(k.astype(k_pool.dtype), mode="drop")
        v_pool = v_pool.at[flat].set(v.astype(v_pool.dtype), mode="drop")
        pos_pool = pos_pool.at[flat].set(pos.astype(pos_pool.dtype), mode="drop")
        val_pool = val_pool.at[flat].set(jnp.ones((b, s), bool), mode="drop")
        new_cache = {"k": k_pool.reshape(cache["k"].shape),
                     "v": v_pool.reshape(cache["v"].shape),
                     "pos": pos_pool.reshape(cache["pos"].shape),
                     "valid": val_pool.reshape(cache["valid"].shape)}
        # gathered per-slot view (b, max_blocks*blk, ...): transient, so
        # persistent memory stays O(pool) while attention sees a dense run
        view = max_blocks * blk
        k = jnp.take(new_cache["k"], paged_table, axis=0).reshape(
            (b, view) + cache["k"].shape[2:]).astype(q.dtype)
        v = jnp.take(new_cache["v"], paged_table, axis=0).reshape(
            (b, view) + cache["v"].shape[2:]).astype(q.dtype)
        kv_positions = jnp.take(new_cache["pos"], paged_table,
                                axis=0).reshape(b, view)
        k_valid = jnp.take(new_cache["valid"], paged_table,
                           axis=0).reshape(b, view)
    elif cache is not None and cache_index is None:
        # Dense scatter insert at per-token absolute positions (serving:
        # any-position batched decode / chunked prefill). Masked writes go
        # to out-of-bounds index L and are dropped.
        L = cache["k"].shape[1]
        pos = kv_positions.astype(jnp.int32)
        slots = pos % L if window is not None else pos
        wmask = (cache_write_mask if cache_write_mask is not None
                 else jnp.ones((b, s), dtype=bool))
        mask = wmask
        if window is not None and s > 1:
            mask = _dedup_ring_slots(slots, pos, mask)
        slots = jnp.where(mask, slots, L)
        b_idx = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], (b, s))
        k_cache = cache["k"].at[b_idx, slots].set(
            k.astype(cache["k"].dtype), mode="drop")
        v_cache = cache["v"].at[b_idx, slots].set(
            v.astype(cache["v"].dtype), mode="drop")
        pos_cache = cache["pos"].at[b_idx, slots].set(
            pos.astype(cache["pos"].dtype), mode="drop")
        valid_cache = cache["valid"].at[b_idx, slots].set(
            jnp.ones((b, s), bool), mode="drop")
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache,
                     "valid": valid_cache}
        if window is not None and s > 1:
            # Ring eviction hazard: the ring is window-sized, so this
            # chunk's writes overwrite slots that EARLIER queries of the
            # SAME chunk still need (query p0 reaches back to p0-L+1,
            # exactly the slots positions p0.. reuse). Attend over the
            # PRE-write ring plus the fresh chunk; the scattered ring
            # above still carries the post-chunk state forward.
            k = jnp.concatenate(
                [cache["k"].astype(q.dtype), k.astype(q.dtype)], axis=1)
            v = jnp.concatenate(
                [cache["v"].astype(q.dtype), v.astype(q.dtype)], axis=1)
            kv_positions = jnp.concatenate([cache["pos"], pos], axis=1)
            k_valid = jnp.concatenate([cache["valid"], wmask], axis=1)
        else:
            k, v = k_cache.astype(q.dtype), v_cache.astype(q.dtype)
            kv_positions = pos_cache
            k_valid = valid_cache
    elif cache is not None:
        # Legacy scalar-index insert (all slots at one position; ring-buffer
        # slot for SWA) — bitwise-unchanged training/eval decode path.
        slot = cache_index % cache["k"].shape[1] if window is not None else cache_index
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        pos_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], kv_positions.astype(cache["pos"].dtype), slot, axis=1)
        valid_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["valid"], jnp.ones((b, s), dtype=bool), slot, axis=1)
        new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache, "valid": valid_cache}
        k, v = k_cache.astype(q.dtype), v_cache.astype(q.dtype)
        kv_positions = pos_cache
        k_valid = valid_cache
    else:
        new_cache = None

    group = n_heads // n_kv_heads
    t = k.shape[1]
    scale = head_dim ** -0.5

    use_flash = (extra_mask is None and cache is None
                 and s * t >= _FLASH_THRESHOLD)
    if use_flash:
        if flash_repeat_kv and group > 1:
            # repeat k/v to full heads: (b, H, s, hd) lays out with the
            # head axis shardable over the tensor-parallel mesh axis even
            # when n_kv_heads doesn't divide it (GQA kv=8 vs model=16).
            kr = jnp.repeat(k, group, axis=2)
            vr = jnp.repeat(v, group, axis=2)
            qf = _pin_heads(jnp.moveaxis(q, 1, 2))       # (b, H, s, hd)
            kf = _pin_heads(jnp.moveaxis(kr, 1, 2))
            vf = _pin_heads(jnp.moveaxis(vr, 1, 2))
            qpos_f = q_positions[:, None, :]
            kpos_f = kv_positions[:, None, :]
            kval_f = None if k_valid is None else k_valid[:, None, :]
            ctx = _pin_heads(flash_attention_ref(
                qf, kf, vf, qpos_f, kpos_f, kval_f, scale,
                causal, window, 512, k_valid is not None))
            ctx = jnp.moveaxis(ctx, 1, 2).reshape(
                b, s, n_heads * head_dim).astype(x.dtype)
        else:
            # (b, kv, g*s, hd) queries against unreplicated (b, kv, t, hd)
            # kv — blockwise online softmax, no (s, t) scores, no k repeat.
            qg = q.reshape(b, s, n_kv_heads, group, head_dim)
            qf = jnp.moveaxis(qg, 1, 3).reshape(
                b, n_kv_heads, group * s, head_dim)
            kf = jnp.moveaxis(k, 1, 2)                   # (b, kv, t, hd)
            vf = jnp.moveaxis(v, 1, 2)
            qpos_f = jnp.broadcast_to(
                q_positions[:, None, None, :], (b, 1, group, s)).reshape(
                b, 1, group * s)
            kpos_f = kv_positions[:, None, :]
            kval_f = None if k_valid is None else k_valid[:, None, :]
            ctx = flash_attention_ref(
                qf, kf, vf, qpos_f, kpos_f, kval_f, scale,
                causal, window, 512, k_valid is not None)
            ctx = jnp.moveaxis(
                ctx.reshape(b, n_kv_heads, group, s, head_dim), 3, 1)
            ctx = ctx.reshape(b, s, n_heads * head_dim).astype(x.dtype)
    else:
        mask = build_mask(q_positions, kv_positions, causal=causal,
                          window=window, k_valid=k_valid)  # (b, q, k)
        if extra_mask is not None:
            mask = mask + extra_mask
        qg = q.reshape(b, s, n_kv_heads, group, head_dim)
        logits = jnp.einsum("bsngh,btnh->bnsgt", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        logits = logits + mask[:, None, :, None, :]
        probs = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("bnsgt,btnh->bsngh", probs, v.astype(jnp.float32))
        ctx = ctx.reshape(b, s, n_heads * head_dim).astype(x.dtype)

    y = ctx @ params["wo"].astype(x.dtype)
    if "bo" in params:
        y = y + params["bo"].astype(x.dtype)

    if cache is not None:
        return y, new_cache
    if return_kv:
        return y, {"k": k, "v": v}
    return y

"""Feed-forward blocks: SwiGLU (LLM default) and GELU (whisper).

`mlp_apply(tp_axis=...)` runs the block Megatron-style inside a
shard_map slice: w_in / w_gate (and b_in) hold a d_ff shard
(column-parallel), w_out holds the matching input-dim shard
(row-parallel), and ONE psum (`reduce_from_tp`) closes the block —
the replicated b_out is added after the reduction, so the result
matches the unsharded block to f32 round-off. The fused [in | gate]
layout (`w_inga`) interleaves both halves on one output dim, which a
contiguous model-axis shard would split across the in/gate boundary —
fused configs therefore reject tp_axis (use fuse_gate=False for TP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import initializers
from repro.nn.tp import copy_to_tp, reduce_from_tp


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             use_bias: bool = False, fuse_gate: bool = False,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if gated and fuse_gate:
        # fused [in | gate]: one matmul fwd, one dx all-reduce bwd under TP
        params = {
            "w_inga": initializers.lecun_normal(ks[0], (d_model, 2 * d_ff),
                                                dtype=dtype),
            "w_out": initializers.lecun_normal(ks[1], (d_ff, d_model),
                                               fan_in=d_ff, dtype=dtype),
        }
        if use_bias:
            params["b_inga"] = jnp.zeros((2 * d_ff,), dtype=dtype)
            params["b_out"] = jnp.zeros((d_model,), dtype=dtype)
        return params
    params = {
        "w_in": initializers.lecun_normal(ks[0], (d_model, d_ff), dtype=dtype),
        "w_out": initializers.lecun_normal(ks[1], (d_ff, d_model), fan_in=d_ff, dtype=dtype),
    }
    if gated:
        params["w_gate"] = initializers.lecun_normal(ks[2], (d_model, d_ff), dtype=dtype)
    if use_bias:
        params["b_in"] = jnp.zeros((d_ff,), dtype=dtype)
        params["b_out"] = jnp.zeros((d_model,), dtype=dtype)
    return params


def mlp_apply(params, x, *, tp_axis=None):
    if "w_inga" in params:
        if tp_axis is not None:
            raise ValueError(
                "fused [in|gate] (fuse_gate=True) cannot be tensor-parallel:"
                " a contiguous model-axis shard of w_inga would split the"
                " in/gate halves; init with fuse_gate=False for TP")
        fused = x @ params["w_inga"].astype(x.dtype)
        if "b_inga" in params:
            fused = fused + params["b_inga"].astype(x.dtype)
        d_ff = fused.shape[-1] // 2
        h = jax.nn.silu(fused[..., d_ff:]) * fused[..., :d_ff]
    else:
        xt = copy_to_tp(x, tp_axis)
        h = xt @ params["w_in"].astype(x.dtype)
        if "b_in" in params:
            h = h + params["b_in"].astype(x.dtype)
        if "w_gate" in params:
            h = jax.nn.silu(xt @ params["w_gate"].astype(x.dtype)) * h
        else:
            h = jax.nn.gelu(h)
    y = reduce_from_tp(h @ params["w_out"].astype(x.dtype), tp_axis)
    if "b_out" in params:
        y = y + params["b_out"].astype(x.dtype)
    return y

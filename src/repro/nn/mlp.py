"""Feed-forward blocks: SwiGLU (LLM default) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import initializers


def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             use_bias: bool = False, fuse_gate: bool = False,
             dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if gated and fuse_gate:
        # fused [in | gate]: one matmul fwd, one dx all-reduce bwd under TP
        params = {
            "w_inga": initializers.lecun_normal(ks[0], (d_model, 2 * d_ff),
                                                dtype=dtype),
            "w_out": initializers.lecun_normal(ks[1], (d_ff, d_model),
                                               fan_in=d_ff, dtype=dtype),
        }
        if use_bias:
            params["b_inga"] = jnp.zeros((2 * d_ff,), dtype=dtype)
            params["b_out"] = jnp.zeros((d_model,), dtype=dtype)
        return params
    params = {
        "w_in": initializers.lecun_normal(ks[0], (d_model, d_ff), dtype=dtype),
        "w_out": initializers.lecun_normal(ks[1], (d_ff, d_model), fan_in=d_ff, dtype=dtype),
    }
    if gated:
        params["w_gate"] = initializers.lecun_normal(ks[2], (d_model, d_ff), dtype=dtype)
    if use_bias:
        params["b_in"] = jnp.zeros((d_ff,), dtype=dtype)
        params["b_out"] = jnp.zeros((d_model,), dtype=dtype)
    return params


def mlp_apply(params, x):
    if "w_inga" in params:
        fused = x @ params["w_inga"].astype(x.dtype)
        if "b_inga" in params:
            fused = fused + params["b_inga"].astype(x.dtype)
        d_ff = fused.shape[-1] // 2
        h = jax.nn.silu(fused[..., d_ff:]) * fused[..., :d_ff]
    else:
        h = x @ params["w_in"].astype(x.dtype)
        if "b_in" in params:
            h = h + params["b_in"].astype(x.dtype)
        if "w_gate" in params:
            h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * h
        else:
            h = jax.nn.gelu(h)
    y = h @ params["w_out"].astype(x.dtype)
    if "b_out" in params:
        y = y + params["b_out"].astype(x.dtype)
    return y

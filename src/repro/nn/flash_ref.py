"""Memory-efficient (flash) attention in pure jnp with a custom VJP.

Never materializes the (s_q, s_k) score matrix: the forward pass scans
KV blocks with an online softmax; the backward pass (FlashAttention-2
style) rescans blocks, recomputing block scores from the saved
(q, k, v, out, lse). Exact — not an approximation.

Used as (a) the training-path attention for long sequences (the naive
path allocates b*h*s^2 floats, ~3 GB/layer/chip for the 4k shapes) and
(b) the numerical oracle for the Pallas TPU kernel
(repro.kernels.flash_attn).

Layout: q (b, h, sq, d); k, v (b, h, sk, d). GQA callers fold the group
into the query-length axis so k/v are never repeated.
Masking is positional: causal, sliding window, and a key-validity mask,
all computed blockwise from integer positions.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_bias(q_pos, k_pos, causal: bool, window: Optional[int], k_valid):
    """(..., sq, bk) additive f32 bias for one KV block."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    allowed = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), dtype=bool)
    if causal:
        allowed &= kp <= qp
    if window is not None:
        allowed &= kp > qp - window
    if k_valid is not None:
        allowed &= k_valid[..., None, :]
    return jnp.where(allowed, 0.0, NEG_INF)


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10))
def flash_attention_ref(q, k, v, q_pos, k_pos, k_valid, scale,
                        causal: bool = True, window: Optional[int] = None,
                        block_k: int = 512, use_valid: bool = False):
    out, _lse = _flash_fwd_inner(q, k, v, q_pos, k_pos, k_valid, scale,
                                 causal, window, block_k, use_valid)
    return out


def _flash_fwd_inner(q, k, v, q_pos, k_pos, k_valid, scale,
                     causal, window, block_k, use_valid):
    b_shape = q.shape[:-2]
    sq, d = q.shape[-2:]
    sk = k.shape[-2]
    bk = min(block_k, sk)
    pad = (-sk) % bk
    if pad:
        k = jnp.pad(k, [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)])
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad), (0, 0)])
        k_pos = jnp.pad(k_pos, [(0, 0)] * (k_pos.ndim - 1) + [(0, pad)],
                        constant_values=jnp.iinfo(jnp.int32).max)
        if k_valid is None:
            k_valid = jnp.ones(k_pos.shape, dtype=bool).at[..., sk:].set(False)
            use_valid = True
        else:
            k_valid = jnp.pad(k_valid,
                              [(0, 0)] * (k_valid.ndim - 1) + [(0, pad)])
    n_blocks = k.shape[-2] // bk

    qf = q.astype(jnp.float32) * scale

    def body(carry, i):
        acc, m_run, l_run = carry
        sl = (i * bk, bk)
        kb = jax.lax.dynamic_slice_in_dim(k, sl[0], bk, axis=-2)
        vb = jax.lax.dynamic_slice_in_dim(v, sl[0], bk, axis=-2)
        kpb = jax.lax.dynamic_slice_in_dim(k_pos, sl[0], bk, axis=-1)
        kvb = (jax.lax.dynamic_slice_in_dim(k_valid, sl[0], bk, axis=-1)
               if use_valid and k_valid is not None else None)
        s = jnp.einsum("...qd,...kd->...qk", qf, kb.astype(jnp.float32))
        s = s + _block_bias(q_pos, kpb, causal, window, kvb)
        m_new = jnp.maximum(m_run, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "...qk,...kd->...qd", p, vb.astype(jnp.float32))
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros(b_shape + (sq, d), dtype=jnp.float32)
    m0 = jnp.full(b_shape + (sq,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros(b_shape + (sq,), dtype=jnp.float32)
    (acc, m_run, l_run), _ = jax.lax.scan(
        body, (acc0, m0, l0), jnp.arange(n_blocks))
    l_safe = jnp.maximum(l_run, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    lse = m_run + jnp.log(l_safe)
    return out, lse


def _flash_fwd(q, k, v, q_pos, k_pos, k_valid, scale,
               causal, window, block_k, use_valid):
    out, lse = _flash_fwd_inner(q, k, v, q_pos, k_pos, k_valid, scale,
                                causal, window, block_k, use_valid)
    return out, (q, k, v, q_pos, k_pos, k_valid, scale, out, lse)


def _flash_bwd(causal, window, block_k, use_valid, res, dout):
    q, k, v, q_pos, k_pos, k_valid, scale, out, lse = res
    sk = k.shape[-2]
    bk = min(block_k, sk)
    pad = (-sk) % bk
    kp, vp = k, v
    kpos_p, kval_p = k_pos, k_valid
    if pad:
        kp = jnp.pad(k, [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)])
        vp = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad), (0, 0)])
        kpos_p = jnp.pad(k_pos, [(0, 0)] * (k_pos.ndim - 1) + [(0, pad)],
                         constant_values=jnp.iinfo(jnp.int32).max)
        if k_valid is not None:
            kval_p = jnp.pad(k_valid,
                             [(0, 0)] * (k_valid.ndim - 1) + [(0, pad)])
    n_blocks = kp.shape[-2] // bk

    qf = q.astype(jnp.float32) * scale
    dof = dout.astype(jnp.float32)
    # delta = rowsum(dO * O)
    delta = jnp.sum(dof * out.astype(jnp.float32), axis=-1)

    def body(carry, i):
        dq_acc, dk_acc, dv_acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kp, i * bk, bk, axis=-2)
        vb = jax.lax.dynamic_slice_in_dim(vp, i * bk, bk, axis=-2)
        kpb = jax.lax.dynamic_slice_in_dim(kpos_p, i * bk, bk, axis=-1)
        kvb = (jax.lax.dynamic_slice_in_dim(kval_p, i * bk, bk, axis=-1)
               if use_valid and kval_p is not None else None)
        s = jnp.einsum("...qd,...kd->...qk", qf, kb.astype(jnp.float32))
        s = s + _block_bias(q_pos, kpb, causal, window, kvb)
        p = jnp.exp(s - lse[..., None])                      # exact probs
        dp = jnp.einsum("...qd,...kd->...qk", dof, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("...qk,...kd->...qd", ds,
                                     kb.astype(jnp.float32)) * scale
        dkb = jnp.einsum("...qk,...qd->...kd", ds, qf)
        dvb = jnp.einsum("...qk,...qd->...kd", p, dof)
        dk_acc = jax.lax.dynamic_update_slice_in_dim(
            dk_acc, dkb.astype(dk_acc.dtype), i * bk, axis=-2)
        dv_acc = jax.lax.dynamic_update_slice_in_dim(
            dv_acc, dvb.astype(dv_acc.dtype), i * bk, axis=-2)
        return (dq_acc, dk_acc, dv_acc), None

    dq0 = jnp.zeros(q.shape, dtype=jnp.float32)
    dk0 = jnp.zeros(kp.shape, dtype=jnp.float32)
    dv0 = jnp.zeros(vp.shape, dtype=jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0),
                                   jnp.arange(n_blocks))
    if pad:
        dk = dk[..., :sk, :]
        dv = dv[..., :sk, :]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None, None)


flash_attention_ref.defvjp(_flash_fwd, _flash_bwd)

"""Oracle for the flash_attn kernel: the (grad-tested) blockwise jnp
implementation, plus a naive softmax for cross-checks."""
import jax
import jax.numpy as jnp

from repro.nn.flash_ref import flash_attention_ref, _block_bias


def flash_ref(q, k, v, *, scale, causal=True, window=None):
    """q (BH, SQ, D); k/v (BH, SK, D); q row r at absolute position
    SK - SQ + r (suffix alignment, matching the kernel wrapper)."""
    bh, sq, _ = q.shape
    sk = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(sk - sq, sk), (bh, sq))
    k_pos = jnp.broadcast_to(jnp.arange(sk), (bh, sk))
    return flash_attention_ref(q, k, v, q_pos, k_pos, None, scale,
                               causal, window, 512, False)


def naive_ref(q, k, v, *, scale, causal=True, window=None):
    bh, sq, _ = q.shape
    sk = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(sk - sq, sk), (bh, sq))
    k_pos = jnp.broadcast_to(jnp.arange(sk), (bh, sk))
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = s + _block_bias(q_pos, k_pos, causal, window, None)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)

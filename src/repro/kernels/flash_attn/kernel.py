"""Flash-attention forward (online softmax) as a Pallas TPU kernel.

Serving-prefill hot path: causal (optionally sliding-window) attention
without materializing (sq, sk) scores. Grid (BH, n_q_blocks,
n_k_blocks), k innermost; the running (acc, m, l) statistics persist in
VMEM scratch across the k iterations of one q block — TPU grids iterate
sequentially, making this the canonical carry pattern.

Block shapes: BQ=128 query rows x full head_dim (64..256) x BK=128 key
rows — MXU-aligned (128 lanes) and ~0.5 MB/block of VMEM in f32.
Fully-masked k blocks (beyond the causal frontier or outside the
window) are skipped with pl.when so SWA costs O(s * window).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int):
    jq = pl.program_id(1)
    jk = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = jq * bq
    k_start = jk * bk
    # block-level reachability: any (i, j) with j <= i and j > i - window?
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window is not None:
        # newest query must still see the oldest key of the block
        reachable = jnp.logical_and(
            reachable, k_start + bk - 1 > q_start - window)

    @pl.when(reachable)
    def _attend():
        q = q_ref[0].astype(jnp.float32) * scale       # (BQ, D)
        k = k_ref[0].astype(jnp.float32)               # (BK, D)
        v = v_ref[0].astype(jnp.float32)               # (BK, D)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), dtype=bool)
        if causal:
            ok &= k_pos <= q_pos
        if window is not None:
            ok &= k_pos > q_pos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + p.sum(-1)
        m_ref[...] = m_new
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p, v, preferred_element_type=jnp.float32))

    @pl.when(jk == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "causal", "window",
                                             "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, scale: float, causal: bool = True,
                           window=None, bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q (BH, SQ, D), k/v (BH, SK, D) -> (BH, SQ, D).
    SQ % bq == 0 and SK % bk == 0 (ops.py pads; padded keys are masked by
    causality/window given q positions start at SK - SQ... ops.py handles
    alignment so that q row r has absolute position r)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0
    grid = (bh, sq // bq, sk // bk)
    kern = functools.partial(_flash_kernel, scale=scale, causal=causal,
                             window=window, bq=bq, bk=bk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, jq, jk: (i, jq, 0)),
            pl.BlockSpec((1, bk, d), lambda i, jq, jk: (i, jk, 0)),
            pl.BlockSpec((1, bk, d), lambda i, jq, jk: (i, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, jq, jk: (i, jq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

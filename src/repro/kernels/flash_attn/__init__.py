from repro.kernels.flash_attn import ops, ref

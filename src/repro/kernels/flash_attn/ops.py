"""Jit'd wrapper: GQA folding, padding to block multiples, and the
(b, s, heads, head_dim) <-> (BH, S, D) layout moves."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas

_INTERPRET = jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, n_kv_heads: int, causal: bool = True,
                    window=None, bq: int = 128, bk: int = 128,
                    interpret: bool | None = None):
    """Self-attention forward.

    q: (b, s, n_heads, hd); k, v: (b, s, n_kv_heads, hd). GQA is handled
    by folding the group into the batch*kv axis on the query side — k/v
    are never repeated. Returns (b, s, n_heads, hd).
    """
    if interpret is None:
        interpret = _INTERPRET
    b, s, nh, hd = q.shape
    nkv = n_kv_heads
    g = nh // nkv
    scale = hd ** -0.5

    pad = (-s) % max(bq, bk)
    sp = s + pad
    bq_, bk_ = min(bq, sp), min(bk, sp)

    # (b, s, kv, g, hd) -> (b*kv, g*sp, hd): queries of one kv-group share
    # that group's keys. We keep g separate by running g*sq rows per head
    # only when positions stay aligned — instead fold g into BH with k/v
    # broadcast-by-view (no materialized repeat thanks to reshape+tile of
    # the same buffer being fused by XLA).
    qg = q.reshape(b, s, nkv, g, hd)
    qg = jnp.moveaxis(qg, (2, 3), (1, 2)).reshape(b * nkv * g, s, hd)
    kg = jnp.moveaxis(k, 2, 1)                       # (b, kv, s, hd)
    kg = jnp.repeat(kg, g, axis=1).reshape(b * nkv * g, s, hd)
    vg = jnp.moveaxis(v, 2, 1)
    vg = jnp.repeat(vg, g, axis=1).reshape(b * nkv * g, s, hd)

    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0)))
        kg = jnp.pad(kg, ((0, 0), (0, pad), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, pad), (0, 0)))

    out = flash_attention_pallas(qg, kg, vg, scale=scale, causal=causal,
                                 window=window, bq=bq_, bk=bk_,
                                 interpret=interpret)
    out = out[:, :s].reshape(b, nkv, g, s, hd)
    out = jnp.moveaxis(out, (1, 2), (2, 3)).reshape(b, s, nh, hd)
    return out

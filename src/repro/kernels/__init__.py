"""Pallas TPU kernels for the framework's compute hot spots.

  wavg        Algorithm 2 — weighted discriminator averaging (the paper's
              central server-side op), blocked over the flattened
              parameter vector.
  ssd_scan    Mamba-2 SSD chunked scan (mamba2/zamba2 mixers).
  flash_attn  online-softmax attention forward (serving prefill).

Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with padding/layout), ref.py (pure-jnp oracle). Kernels are
TPU-targeted; on this CPU container they are validated with
interpret=True (the kernel body runs in Python)."""

"""Order-independent float64 reference for the ring reduction."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import quantize


def ring_average_ref(stacked_tree, weights, *, round_key=None,
                     bits: int = 32):
    """sum_k w_norm[k] * dequant_k(tree_k), reduced in float64 numpy —
    the order-independent twin the seeded property tests pin the ring
    (and flat) collectives against. Quantization (bits < 32 with a
    round_key) goes through the SAME `quantize_tree` streams as the
    on-wire path, so the only thing under test is reduction order and
    precision, never the quantized values."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked_tree)
    k = leaves[0].shape[0]
    w = np.asarray(weights, dtype=np.float64)
    w_norm = w / max(float(w.sum()), 1e-12)
    acc = [np.zeros(x.shape[1:], np.float64) for x in leaves]
    for i in range(k):
        dev = jax.tree_util.tree_unflatten(treedef, [x[i] for x in leaves])
        if bits < 32 and round_key is not None:
            key = quantize.device_uplink_key(round_key, i)
            q, s = quantize.quantize_tree(key, dev, bits)
            dev = quantize.dequantize_tree(q, s)
        for j, leaf in enumerate(jax.tree_util.tree_leaves(dev)):
            acc[j] = acc[j] + w_norm[i] * np.asarray(leaf, np.float64)
    out = [a.astype(np.asarray(x).dtype) for a, x in zip(acc, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)

"""Dequantize-and-accumulate Pallas kernel for the ring reduction.

    out[b, :] = acc[b, :] + coef[b] * q[b, :]

One grid step per BLOCK_N wire block. `q` is the ENCODED uplink payload
(int16 for the paper's 16-bit quantizer, int32 for 17..31 bits, f32 for
unquantized) and `coef[b] = w_norm[src] * scale[b]` folds the source
worker's normalized Algorithm-2 weight AND its per-tensor quantization
scale into one in-register multiplier — the payload is decoded during
the accumulate, so no per-rank f32 tree is ever materialized.
`input_output_aliases` updates the f32 accumulator in place: the ring
(ops.py) calls this once per received chunk per hop.

BLOCK_N is shared with the flat `wavg` kernel so both hot paths tile
HBM->VMEM identically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.wavg.kernel import BLOCK_N


def _ring_accum_kernel(coef_ref, q_ref, acc_ref, o_ref):
    # coef: (1, 1) f32, q: (1, BN) wire dtype, acc/out: (1, BN) f32
    o_ref[...] = (acc_ref[...]
                  + coef_ref[0, 0] * q_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def ring_accum_pallas(acc, q, coef, *, interpret: bool = False):
    """acc: (nb, BLOCK_N) f32 accumulator; q: (nb, BLOCK_N) wire blocks;
    coef: (nb,) f32 per-block multiplier. Returns the updated
    accumulator (aliased onto `acc`)."""
    nb, bn = acc.shape
    assert bn == BLOCK_N, "ops.py pads the wire payload to BLOCK_N"
    assert q.shape == acc.shape and coef.shape == (nb,)
    return pl.pallas_call(
        _ring_accum_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),        # coef
            pl.BlockSpec((1, BLOCK_N), lambda i: (i, 0)),  # wire block
            pl.BlockSpec((1, BLOCK_N), lambda i: (i, 0)),  # accumulator
        ],
        out_specs=pl.BlockSpec((1, BLOCK_N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, bn), jnp.float32),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(coef.reshape(nb, 1).astype(jnp.float32), q, acc)

"""Algorithm 2 as a chunked, double-buffered ring collective.

The flat hot path (`averaging.weighted_average_psum(impl="pallas")`)
all-gathers every worker's FULL f32 payload before reducing — per-rank
wire bytes grow as K * N * 4 even when the uplink was quantized to 16
bits, because the payload is dequantized BEFORE the collective. This
module replaces it for ``impl="ring"``:

  * the uplink payload stays ENCODED on the wire (int16 at the paper's
    16 bits; int32 for 17..31; f32 when unquantized), reshaped into
    (n_blocks, BLOCK_N) wire blocks with a travelling (n_blocks,) f32
    per-block scale vector (each leaf's per-tensor scale broadcast over
    its blocks);
  * the reduction is k-1 `lax.ppermute` hops around the device ring;
    after hop h every rank holds worker (my - h) mod k's payload and
    accumulates coef = w_norm[src] * scale into a resident f32
    accumulator via the `ring_accum` Pallas kernel — dequantize fused
    into the accumulate, no per-rank f32 tree materialized;
  * each hop is CHUNKED (default 4 chunks): chunk c+1's permute is
    issued before chunk c's accumulate kernel runs, so XLA's async
    collective-permute overlaps the wire transfer of the next chunk
    with the reduction of the current one (double buffering).

Per-rank wire bytes: (k-1) * n_blocks * (BLOCK_N * wire_itemsize + 4)
vs the flat path's k * N * 4 — about 2x less at 16 bits (pinned by
tests/test_hlo_costs.py against what the HLO actually moves).

Quantization reuses `core.quantize.quantize_tree` with the SAME
`device_uplink_key` stream as the flat path's roundtrip, so the ring
changes only reduction order/precision, never the quantized values.
Restrictions (checked by `shard_round.check_ring_support` at build
time): single device axis, tp == 1, no robust reducers, no
upload-corrupting fault programs (those operate on dequantized trees
and stay on the flat path). Dropout/straggler faults compose fine —
they only zero weights.

No-survivor semantics: when every weight is zero (all workers dropped)
the average is undefined; with ``fallback`` the previous global
parameters are kept instead of the ~0 tree that `max(total, 1e-12)`
normalization would produce.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantize
from repro.kernels.ring_wavg.kernel import BLOCK_N, ring_accum_pallas

_INTERPRET = jax.default_backend() == "cpu"

# Chunks per hop: enough to overlap permute/accumulate without
# shrinking blocks below useful DMA sizes at small payloads.
DEFAULT_CHUNKS = 4


def _single_axis(axis_names):
    if isinstance(axis_names, (tuple, list)):
        if len(axis_names) != 1:
            raise NotImplementedError(
                f"impl='ring' reduces over a single device axis; "
                f"got {axis_names!r}")
        return axis_names[0]
    return axis_names


def wire_dtype(bits: int):
    """Wire dtype for the encoded payload at a given uplink bit width.
    quantize_tree clips to [-levels-1, levels] = [-2**(bits-1),
    2**(bits-1)-1], so bits <= 16 fits int16 exactly."""
    if bits >= 32:
        return jnp.float32
    return jnp.int16 if bits <= 16 else jnp.int32


def ring_wire_bytes_per_rank(tree, bits: int, k: int) -> int:
    """Analytic per-rank bytes sent by the ring: (k-1) hops, each moving
    the padded wire payload plus the travelling block-scale vector.
    The twin of `driver_bench.allgather_bytes_per_rank` for the flat
    path; pinned against the lowered HLO in tests/test_hlo_costs.py."""
    sizes = [int(x.size) for x in jax.tree_util.tree_leaves(tree)]
    n_blocks = sum(-(-s // BLOCK_N) for s in sizes)
    itemsize = jnp.dtype(wire_dtype(bits)).itemsize
    return (k - 1) * n_blocks * (BLOCK_N * itemsize + 4)


def _chunk_bounds(n_blocks: int, n_chunks: int):
    """Static block-row ranges per chunk; ragged last chunks (no extra
    chunk-multiple padding — at most 2 distinct kernel shapes)."""
    n_chunks = max(1, min(n_chunks, n_blocks))
    base, rem = divmod(n_blocks, n_chunks)
    bounds, r0 = [], 0
    for c in range(n_chunks):
        r1 = r0 + base + (1 if c < rem else 0)
        bounds.append((r0, r1))
        r0 = r1
    return bounds


def _encode(local_params, quantize_key, bits: int):
    """Leaf trees -> ((n_blocks, BLOCK_N) wire payload, (n_blocks,) f32
    block scales, per-leaf metadata for decode)."""
    leaves, treedef = jax.tree_util.tree_flatten(local_params)
    metas = [(x.shape, x.dtype, int(x.size)) for x in leaves]
    wdt = wire_dtype(bits)
    if quantize_key is not None and bits < 32:
        q_tree, s_tree = quantize.quantize_tree(quantize_key, local_params,
                                                bits)
        q_leaves = jax.tree_util.tree_leaves(q_tree)
        s_leaves = jax.tree_util.tree_leaves(s_tree)
    else:
        q_leaves = leaves
        s_leaves = [jnp.asarray(1.0, jnp.float32) for _ in leaves]
    blocks, bscales = [], []
    for q, s in zip(q_leaves, s_leaves):
        flat = jnp.ravel(q).astype(wdt)
        pad = (-flat.size) % BLOCK_N
        if pad:
            flat = jnp.pad(flat, (0, pad))
        nb = flat.size // BLOCK_N
        blocks.append(flat.reshape(nb, BLOCK_N))
        bscales.append(jnp.broadcast_to(
            jnp.asarray(s, jnp.float32).reshape(()), (nb,)))
    return (jnp.concatenate(blocks, axis=0),
            jnp.concatenate(bscales), metas, treedef)


def _decode(acc, metas, treedef):
    out, row = [], 0
    for shape, dtype, size in metas:
        nb = -(-size // BLOCK_N)
        flat = acc[row:row + nb].reshape(-1)[:size]
        out.append(flat.reshape(shape).astype(dtype))
        row += nb
    return jax.tree_util.tree_unflatten(treedef, out)


def ring_average_psum(local_params, local_weight, *, axis_names,
                      quantize_key=None, bits: int = 32,
                      n_chunks: Optional[int] = None,
                      interpret: Optional[bool] = None, fallback=None):
    """Ring-collective Algorithm 2: the `weighted_average_psum` twin for
    ``impl="ring"``. Every mesh slice holds ITS device's parameters;
    returns the weighted average, replicated on every slice.

    quantize_key/bits: when bits < 32 and a key is given, the payload is
    quantized with `quantize.quantize_tree` (same stream as the flat
    path's uplink roundtrip) and travels encoded. fallback: pytree
    shaped like `local_params`; returned when the total weight is zero
    (no-survivor round).
    """
    axis = _single_axis(axis_names)
    if interpret is None:
        interpret = _INTERPRET
    if not jax.tree_util.tree_leaves(local_params):
        return local_params

    k = int(jax.lax.psum(1, axis))          # static ring size
    my = jax.lax.axis_index(axis)
    w_full = jax.lax.all_gather(local_weight.astype(jnp.float32), axis)
    total = jnp.sum(w_full)
    w_norm = w_full / jnp.maximum(total, 1e-12)

    payload, scales, metas, treedef = _encode(local_params, quantize_key,
                                              bits)
    n_blocks = payload.shape[0]
    bounds = _chunk_bounds(
        n_blocks, DEFAULT_CHUNKS if n_chunks is None else n_chunks)

    # Hop 0: accumulate the rank's OWN contribution (no wire traffic).
    acc = ring_accum_pallas(jnp.zeros(payload.shape, jnp.float32),
                            payload, w_norm[my] * scales,
                            interpret=interpret)

    if k > 1:
        perm = [(j, (j + 1) % k) for j in range(k)]

        def hop(carry, h):
            buf, sbuf, acc = carry
            # The block scales travel with the payload: after this hop
            # every rank holds the scales of worker (my - h) mod k.
            sbuf = jax.lax.ppermute(sbuf, axis, perm)
            src = jnp.mod(my - h, k)
            coef = w_norm[src] * sbuf
            # Double buffering: chunk c+1's permute is issued BEFORE
            # chunk c's accumulate so the async collective-permute
            # overlaps the next transfer with the current reduction.
            recv = [jax.lax.ppermute(buf[bounds[0][0]:bounds[0][1]],
                                     axis, perm)]
            accs = []
            for c, (r0, r1) in enumerate(bounds):
                if c + 1 < len(bounds):
                    n0, n1 = bounds[c + 1]
                    recv.append(jax.lax.ppermute(buf[n0:n1], axis, perm))
                accs.append(ring_accum_pallas(acc[r0:r1], recv[c],
                                              coef[r0:r1],
                                              interpret=interpret))
            nbuf = recv[0] if len(recv) == 1 else jnp.concatenate(recv, 0)
            nacc = accs[0] if len(accs) == 1 else jnp.concatenate(accs, 0)
            return (nbuf, sbuf, nacc), None

        (_, _, acc), _ = jax.lax.scan(hop, (payload, scales, acc),
                                      jnp.arange(1, k))

    avg = _decode(acc, metas, treedef)
    if fallback is not None:
        avg = jax.tree.map(
            lambda a, f: jnp.where(total > 0, a, f.astype(a.dtype)),
            avg, fallback)
    return avg


__all__ = ["ring_average_psum", "ring_wire_bytes_per_rank", "wire_dtype",
           "ring_accum_pallas", "BLOCK_N", "DEFAULT_CHUNKS"]

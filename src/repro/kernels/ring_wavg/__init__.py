"""Ring-collective Algorithm 2: chunked double-buffered ppermute ring
with dequantize-and-accumulate fused into the Pallas kernel."""
from repro.kernels.ring_wavg.ops import (  # noqa: F401
    ring_average_psum, ring_wire_bytes_per_rank)

"""Coordinate trimmed-mean as a Pallas TPU kernel — the robust variant
of the Algorithm-2 `wavg` reduction.

    out[n] = sum_{k in S_n} w[k] x[k, n] / sum_{k in S_n} w[k]

where S_n starts as the participants (w[k] > 0) and, per coordinate n,
`trim` (max, min) PAIRS of extreme values are removed — classic
coordinate-wise trimmed mean, weighted. The effective trim count is
clamped so at least one participant survives per coordinate:
pair i is removed only while n_participants >= 2 i + 3.

The stacked payload streams through VMEM in the same (K, BN) tiles as
the `wavg` kernel (BLOCK_N shared), but the reduction is a VPU
masked-select-and-reduce rather than an MXU matmul: each of the
`trim` unrolled steps finds the per-column masked max (then min) and
knocks out its FIRST row occurrence (ties broken by lowest worker
index — exactly reproducible in the numpy ref twin, and load-bearing:
free-riders replaying identical stale payloads produce real ties).

Weights are the RAW participation-aware weights (0 = dropped/straggler)
— normalization happens per coordinate inside the kernel, because the
surviving set S_n differs per coordinate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.wavg.kernel import BLOCK_N


def _trimmed_kernel(w_ref, x_ref, o_ref, *, trim: int, k: int):
    # w: (1, K) f32 raw weights, x: (K, BN), out: (1, BN)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32).reshape(k, 1)      # (K, 1)
    part = w > 0.0                                        # (K, 1)
    inc = jnp.broadcast_to(part, x.shape)                 # (K, BN)
    ridx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    n_part = jnp.sum(part.astype(jnp.int32))

    for i in range(trim):
        # per-column constant gate: trim pair i only while a strict
        # majority of participants would survive (>= 1 row after it)
        gate = n_part >= 2 * i + 3
        big = jnp.where(inc, x, -jnp.inf)
        mx = jnp.max(big, axis=0, keepdims=True)
        is_mx = inc & (big == mx)
        first = jnp.min(jnp.where(is_mx, ridx, k), axis=0, keepdims=True)
        rem_max = is_mx & (ridx == first)
        inc_mid = inc & ~rem_max
        small = jnp.where(inc_mid, x, jnp.inf)
        mn = jnp.min(small, axis=0, keepdims=True)
        is_mn = inc_mid & (small == mn)
        first = jnp.min(jnp.where(is_mn, ridx, k), axis=0, keepdims=True)
        rem_min = is_mn & (ridx == first)
        inc = jnp.where(gate, inc & ~(rem_max | rem_min), inc)

    wk = jnp.where(inc, jnp.broadcast_to(w, x.shape), 0.0)
    num = jnp.sum(wk * x, axis=0, keepdims=True)
    den = jnp.sum(wk, axis=0, keepdims=True)
    o_ref[...] = (num / jnp.maximum(den, 1e-12)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("trim", "interpret"))
def trimmed_wavg_pallas(x, w, *, trim: int, interpret: bool = False):
    """x: (K, N) stacked payload; w: (K,) RAW weights -> (N,) f32."""
    k, n = x.shape
    assert n % BLOCK_N == 0, "ops.py pads N to BLOCK_N"
    grid = (n // BLOCK_N,)
    out = pl.pallas_call(
        functools.partial(_trimmed_kernel, trim=trim, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),          # weights
            pl.BlockSpec((k, BLOCK_N), lambda i: (0, i)),    # param tile
        ],
        out_specs=pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(w.reshape(1, k).astype(jnp.float32), x.astype(jnp.float32))
    return out[0]

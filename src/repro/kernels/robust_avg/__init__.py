from repro.kernels.robust_avg import ops, ref
from repro.kernels.robust_avg.ops import ROBUST_METHODS, RobustConfig

__all__ = ["ops", "ref", "ROBUST_METHODS", "RobustConfig"]

"""Robust Algorithm-2 reducers over the all-gathered flat payload —
alternate `impl`s of `core.averaging.weighted_average_psum` for hostile
worker populations (core/faults.py).

Every method keeps the mesh hot path at ONE all-gather + ONE Pallas
kernel call per round (pinned in tests/test_kernels.py):

  trimmed_mean — the dedicated Pallas kernel (kernel.py): per-
      coordinate masked extreme-pair removal + weighted mean, VPU
      select-and-reduce over the same (K, BN) tiles as `wavg`.
  norm_clip    — per-row L2 norms and the median-norm clip threshold
      are O(K) jnp on the already-gathered matrix; the clipped
      EFFECTIVE WEIGHTS feed the existing `wavg` MXU kernel.
  krum         — multi-Krum scoring from ONE (K, K) Gram matmul on the
      gathered matrix; the selected-set weights feed the `wavg` kernel.

Weights are RAW participation-aware weights (0 = dropped worker), so
dropped workers contribute zero without changing the payload shape.
Identity regimes (all-honest == plain wavg, bitwise on the weight
vector): trim=0, clip_factor large enough that no row clips, or
krum_f=0 (selects every participant).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.robust_avg.kernel import trimmed_wavg_pallas
from repro.kernels.wavg.kernel import BLOCK_N
from repro.kernels.wavg import ops as wavg_ops

_INTERPRET = jax.default_backend() == "cpu"

ROBUST_METHODS = ("trimmed_mean", "norm_clip", "krum")


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Robust-reducer selection + parameters (hashable: part of the
    mesh builder memo keys and `engine.Trainer`'s chunk cache keys)."""
    method: str = "trimmed_mean"
    trim: int = 1                       # (max, min) pairs per coordinate
    clip_factor: float = 2.0            # tau = factor x median norm
    krum_f: int = 1                     # assumed byzantine count
    krum_m: Optional[int] = None        # multi-Krum size (None: n_part - f)

    def __post_init__(self):
        if self.method not in ROBUST_METHODS:
            raise ValueError(f"unknown robust method {self.method!r} "
                             f"(have {ROBUST_METHODS})")
        if self.trim < 0:
            raise ValueError(f"trim must be >= 0 (got {self.trim})")
        if self.clip_factor <= 0:
            raise ValueError(
                f"clip_factor must be > 0 (got {self.clip_factor})")
        if self.krum_f < 0:
            raise ValueError(f"krum_f must be >= 0 (got {self.krum_f})")


def trimmed_average(x, w, *, trim: int, interpret: Optional[bool] = None):
    """Coordinate trimmed mean of x (K, N) with raw weights w (K,) ->
    (N,) f32. Pads N to BLOCK_N for the kernel and slices back (zero
    pad columns are harmless: the output tail is discarded)."""
    if interpret is None:
        interpret = _INTERPRET
    n = x.shape[1]
    pad = (-n) % BLOCK_N
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = trimmed_wavg_pallas(x, w, trim=trim, interpret=interpret)
    return out[:n]


def _masked_median(v, mask):
    """Median of v[mask] (mean of the two middle order statistics, as
    np.median), 0 when the mask is empty."""
    k = v.shape[0]
    s = jnp.sort(jnp.where(mask, v, jnp.inf))
    n_part = jnp.sum(mask.astype(jnp.int32))
    lo = jnp.clip((n_part - 1) // 2, 0, k - 1)
    hi = jnp.clip(n_part // 2, 0, k - 1)
    return jnp.where(n_part > 0, 0.5 * (s[lo] + s[hi]), 0.0)


def clip_weights(x, w, *, clip_factor: float):
    """Norm-clipping as an effective-weight transform: row k scaled by
    s_k = min(1, clip_factor * median participant norm / ||x_k||), and
    the mean normalized by the ORIGINAL weight total (sum w_k s_k x_k /
    sum w_k — clipped rows shrink toward zero). Returns the normalized
    weight vector to feed the `wavg` kernel. With no row clipping the
    scales are exactly 1.0, so the vector is bitwise the plain
    normalized wavg weights."""
    part = w > 0.0
    norms = jnp.sqrt(jnp.sum(x * x, axis=1))
    tau = clip_factor * _masked_median(norms, part)
    scale = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-12))
    w_eff = jnp.where(part, w * scale, 0.0)
    return w_eff / jnp.maximum(jnp.sum(w), 1e-12)


def krum_weights(x, w, *, f: int, m: Optional[int] = None):
    """Multi-Krum selection as an effective-weight transform: score by
    the sum of the q = clamp(n_part - f - 2, 1, K-1) smallest squared
    distances to other participants (one Gram matmul), keep the
    m = max(n_part - f, 1) lowest scores (ties by lowest index), and
    return the selected weights normalized for the `wavg` kernel. With
    f=0 and m=None every participant is selected — bitwise the plain
    normalized weights."""
    k = x.shape[0]
    part = w > 0.0
    n_part = jnp.sum(part.astype(jnp.int32))
    sq = jnp.sum(x * x, axis=1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :]
                     - 2.0 * jnp.dot(x, x.T,
                                     preferred_element_type=jnp.float32),
                     0.0)
    invalid = (~part[:, None] | ~part[None, :]
               | jnp.eye(k, dtype=bool))
    d2 = jnp.where(invalid, jnp.inf, d2)
    q = jnp.clip(n_part - f - 2, 1, k - 1)
    ds = jnp.sort(d2, axis=1)
    take = jnp.arange(k)[None, :] < q
    score = jnp.sum(jnp.where(take & jnp.isfinite(ds), ds, 0.0), axis=1)
    score = jnp.where(part, score, jnp.inf)
    m_sel = jnp.maximum(n_part - f, 1) if m is None else jnp.int32(m)
    m_sel = jnp.clip(m_sel, 1, jnp.maximum(n_part, 1))
    order = jnp.lexsort((jnp.arange(k), score))
    rank = jnp.zeros(k, jnp.int32).at[order].set(jnp.arange(k, dtype=jnp.int32))
    sel = (rank < m_sel) & part
    w_eff = jnp.where(sel, w, 0.0)
    return w_eff / jnp.maximum(jnp.sum(w_eff), 1e-12)


def robust_average(x, w, cfg: RobustConfig, *,
                   interpret: Optional[bool] = None):
    """Robust weighted aggregate of the gathered payload: x (K, N), raw
    weights w (K,) -> (N,) f32. Dispatches per `cfg.method`; norm_clip
    and krum compute effective weights in jnp and reduce with the
    existing `wavg` Pallas kernel, trimmed_mean runs its own kernel —
    every method is one Pallas call on the (K, N) payload."""
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)
    if cfg.method == "trimmed_mean":
        return trimmed_average(x, w, trim=cfg.trim, interpret=interpret)
    if cfg.method == "norm_clip":
        v = clip_weights(x, w, clip_factor=cfg.clip_factor)
    elif cfg.method == "krum":
        v = krum_weights(x, w, f=cfg.krum_f, m=cfg.krum_m)
    else:
        raise ValueError(cfg.method)
    return wavg_ops.weighted_average(x, v, interpret=interpret)

"""Numpy oracles for the robust reducers — the canonical semantics the
kernel/ops paths are property-tested against (tests/
test_robust_avg_property.py).

All three take the all-gathered payload matrix x (K, N) and RAW
participation-aware weights w (K,) (0 = dropped worker) and return the
robust weighted aggregate (N,) in float.

Tie-breaking and clamping rules are part of the contract (free-riders
replaying identical stale payloads produce EXACT value ties):

  trimmed_mean — per coordinate, remove `trim` (max, min) pairs from
      the participants, each time knocking out the FIRST (lowest
      worker index) occurrence of the extreme value; pair i is removed
      only while n_participants >= 2 i + 3; renormalize the surviving
      weights per coordinate.
  norm_clip — scale row k by min(1, clip_factor * median participant
      norm / ||x_k||); average the scaled rows with the ORIGINAL
      weights (sum w_k s_k x_k / sum w_k) — the DP-FedAvg-style
      clipped mean, so oversized uploads shrink toward zero instead of
      being re-inflated.
  krum — multi-Krum: score_k = sum of the q = clamp(n_part - f - 2,
      1, K-1) smallest squared distances to OTHER participants;
      select the m = max(n_part - f, 1) lowest-scoring participants
      (ties by lowest index) — or an explicit m override — and take
      their plain weighted mean.
"""
from __future__ import annotations

import numpy as np


def trimmed_mean_ref(x, w, *, trim: int):
    x = np.asarray(x, np.float32)
    w = np.asarray(w, np.float32)
    k, n = x.shape
    part = w > 0.0
    inc = np.broadcast_to(part[:, None], x.shape).copy()
    ridx = np.broadcast_to(np.arange(k, dtype=np.int64)[:, None], x.shape)
    n_part = int(part.sum())

    for i in range(trim):
        if n_part < 2 * i + 3:
            break
        big = np.where(inc, x, -np.inf)
        mx = big.max(axis=0, keepdims=True)
        is_mx = inc & (big == mx)
        first = np.where(is_mx, ridx, k).min(axis=0, keepdims=True)
        rem_max = is_mx & (ridx == first)
        inc_mid = inc & ~rem_max
        small = np.where(inc_mid, x, np.inf)
        mn = small.min(axis=0, keepdims=True)
        is_mn = inc_mid & (small == mn)
        first = np.where(is_mn, ridx, k).min(axis=0, keepdims=True)
        rem_min = is_mn & (ridx == first)
        inc = inc & ~(rem_max | rem_min)

    wk = np.where(inc, w[:, None], 0.0).astype(np.float64)
    num = (wk * x.astype(np.float64)).sum(axis=0)
    den = wk.sum(axis=0)
    return num / np.maximum(den, 1e-12)


def norm_clip_ref(x, w, *, clip_factor: float):
    x = np.asarray(x, np.float64)
    w = np.asarray(w, np.float64)
    part = w > 0.0
    norms = np.sqrt((x * x).sum(axis=1))
    med = np.median(norms[part]) if part.any() else 0.0
    tau = clip_factor * med
    scale = np.minimum(1.0, tau / np.maximum(norms, 1e-12))
    w_eff = np.where(part, w * scale, 0.0)
    return (w_eff[:, None] * x).sum(axis=0) / np.maximum(w.sum(), 1e-12)


def krum_selection_ref(x, w, *, f: int, m=None):
    """(K,) bool — the multi-Krum selected set (shared with ops twin
    tests so selection, not just the final mean, is pinned)."""
    x = np.asarray(x, np.float64)
    w = np.asarray(w, np.float64)
    k = x.shape[0]
    part = w > 0.0
    n_part = int(part.sum())
    if n_part == 0:
        return np.zeros(k, bool)
    sq = (x * x).sum(axis=1)
    d2 = np.maximum(sq[:, None] + sq[None, :] - 2.0 * (x @ x.T), 0.0)
    invalid = ~part[:, None] | ~part[None, :] | np.eye(k, dtype=bool)
    d2 = np.where(invalid, np.inf, d2)
    q = int(np.clip(n_part - f - 2, 1, k - 1))
    ds = np.sort(d2, axis=1)[:, :q]
    score = np.where(np.isfinite(ds), ds, 0.0).sum(axis=1)
    score = np.where(part, score, np.inf)
    m_sel = max(n_part - f, 1) if m is None else int(m)
    m_sel = int(np.clip(m_sel, 1, n_part))
    order = np.lexsort((np.arange(k), score))
    sel = np.zeros(k, bool)
    sel[order[:m_sel]] = True
    return sel & part


def krum_ref(x, w, *, f: int, m=None):
    x = np.asarray(x, np.float64)
    w = np.asarray(w, np.float64)
    sel = krum_selection_ref(x, w, f=f, m=m)
    w_eff = np.where(sel, w, 0.0)
    return (w_eff[:, None] * x).sum(axis=0) / np.maximum(w_eff.sum(), 1e-12)


def robust_ref(x, w, cfg):
    """Dispatch on a `RobustConfig` (repro.kernels.robust_avg.ops)."""
    if cfg.method == "trimmed_mean":
        return trimmed_mean_ref(x, w, trim=cfg.trim)
    if cfg.method == "norm_clip":
        return norm_clip_ref(x, w, clip_factor=cfg.clip_factor)
    if cfg.method == "krum":
        return krum_ref(x, w, f=cfg.krum_f, m=cfg.krum_m)
    raise ValueError(cfg.method)

"""Jit'd wrapper: (b, s, h, p) mixer layout <-> kernel (BH, S, P) layout,
group expansion, chunk padding, and the `scan_impl` hook consumed by
`repro.nn.ssm.ssd_mixer_apply`."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas

_INTERPRET = jax.default_backend() == "cpu"


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, initial_state=None,
             return_final_state: bool = False, interpret: bool | None = None):
    """Drop-in replacement for repro.nn.ssm.ssd_scan_ref.

    x (b,s,h,p); dt (b,s,h); A (h,); B,C (b,s,g,n).
    initial_state is not supported by the kernel path (prefill starts
    from zero state); callers resume via the reference decode step.
    """
    assert initial_state is None, "kernel path starts from zero state"
    if interpret is None:
        interpret = _INTERPRET
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g

    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad

    # (b, s, h, p) -> (b*h, s, p); expand groups to heads
    xk = jnp.moveaxis(x, 2, 1).reshape(b * h, sp, p)
    dtk = jnp.moveaxis(dt, 2, 1).reshape(b * h, sp)
    a = dtk * jnp.tile(A.astype(jnp.float32), b).reshape(b * h, 1)
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    Bk = jnp.moveaxis(Bh, 2, 1).reshape(b * h, sp, n)
    Ck = jnp.moveaxis(Ch, 2, 1).reshape(b * h, sp, n)

    y, state = ssd_scan_pallas(xk, dtk, a, Bk, Ck, chunk=min(chunk, sp),
                               interpret=interpret)
    y = jnp.moveaxis(y.reshape(b, h, sp, p), 1, 2)[:, :s]
    if return_final_state:
        return y, state.reshape(b, h, n, p)
    return y

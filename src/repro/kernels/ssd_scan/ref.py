"""Oracle for the ssd_scan kernel: the (tested) pure-jnp chunked scan."""
import jax.numpy as jnp

from repro.nn.ssm import ssd_scan_ref


def ssd_ref(x, dt, a, B, C, *, chunk: int = 128):
    """Kernel layout (BH, S, ...) -> same, via the nn reference.

    a = dt * A is already folded, so pass A=a/dt through a rearranged
    call: we reconstruct by calling the reference with per-head A folded
    into dt (the reference multiplies dt*A itself, so give it A=-1 and
    dt=-a ... simpler: inline the recurrence here).
    """
    bh, s, p = x.shape
    n = B.shape[-1]
    # naive sequential recurrence in f64-ish f32
    state = jnp.zeros((bh, n, p), jnp.float32)
    ys = []
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    for t in range(s):
        decay = jnp.exp(af[:, t])                                  # (BH,)
        outer = jnp.einsum("bn,bp->bnp", Bf[:, t],
                           xf[:, t] * dtf[:, t, None])
        state = decay[:, None, None] * state + outer
        ys.append(jnp.einsum("bn,bnp->bp", Cf[:, t], state))
    y = jnp.stack(ys, axis=1).astype(x.dtype)
    return y, state

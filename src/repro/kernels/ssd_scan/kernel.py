"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Per (batch*head) program, the sequence is processed in chunks of L
tokens. Within a chunk the quadratic ("attention-like") term runs on
the MXU; across chunks the state (n, p) recurrence is carried in a VMEM
scratch accumulator. The TPU grid is iterated sequentially with the
chunk axis innermost, so the scratch state persists across chunk steps
of the same (batch*head) program — the canonical Pallas TPU carry
pattern.

Layouts (prepared by ops.py):
  x   (BH, S, P)    per-head inputs
  dt  (BH, S)       softplus'd step sizes
  a   (BH, S)       dt * A  (decay log-rates, negative)
  B   (BH, S, N)    input projections  (groups pre-expanded)
  C   (BH, S, N)    output projections
  y   (BH, S, P)    outputs
  state_out (BH, N, P) final states (for prefill -> decode handoff)

Chunk L=128 and P(head_dim)=64..128, N(d_state)=64..128 keep every
block MXU-shaped (multiples of 8x128 tiles after f32 promotion).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref):
    j = pl.program_id(1)                     # chunk index (innermost)
    nc = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)         # (L, P)
    dt = dt_ref[0].astype(jnp.float32)       # (L,)
    a = a_ref[0].astype(jnp.float32)         # (L,)
    B = b_ref[0].astype(jnp.float32)         # (L, N)
    C = c_ref[0].astype(jnp.float32)         # (L, N)

    L = x.shape[0]
    xdt = x * dt[:, None]
    cs = jnp.cumsum(a)                       # (L,)

    # within-chunk quadratic term: S_il = (C_i . B_l) exp(cs_i - cs_l), l<=i
    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (L, L)
    seg = cs[:, None] - cs[None, :]
    causal = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
    decay_mat = jnp.where(causal, jnp.exp(seg), 0.0)
    y = jnp.dot(scores * decay_mat, xdt,
                preferred_element_type=jnp.float32)               # (L, P)

    # contribution of the carried state: y_i += exp(cs_i) C_i . state
    state = state_ref[...].astype(jnp.float32)                    # (N, P)
    y = y + jnp.exp(cs)[:, None] * jnp.dot(
        C, state, preferred_element_type=jnp.float32)

    # state update: state' = exp(cs_L) state + sum_l exp(cs_L - cs_l) B_l xdt_l
    total = cs[-1]
    decay_states = jnp.exp(total - cs)                            # (L,)
    new_state = jnp.exp(total) * state + jnp.dot(
        (B * decay_states[:, None]).T, xdt,
        preferred_element_type=jnp.float32)                       # (N, P)
    state_ref[...] = new_state

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(j == nc - 1)
    def _emit_state():
        state_out_ref[0] = new_state.astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, a, B, C, *, chunk: int = 128,
                    interpret: bool = False):
    """Returns (y (BH,S,P), final_state (BH,N,P)). S % chunk == 0
    (ops.py pads)."""
    bh, s, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0
    nc = s // chunk
    grid = (bh, nc)

    y, state_out = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),   # x
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),         # dt
            pl.BlockSpec((1, chunk), lambda i, j: (i, j)),         # a
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # B
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),   # C
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, j: (i, j, 0)),   # y
            pl.BlockSpec((1, n, p), lambda i, j: (i, 0, 0)),       # state
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), x.dtype),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, B, C)
    return y, state_out

"""Pure-jnp oracle for the wavg kernel."""
import jax.numpy as jnp


def wavg_ref(x, w):
    """x: (K, N), w: (K,) normalized -> (N,) in x.dtype, f32 accumulate."""
    return jnp.einsum("k,kn->n", w.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(x.dtype)

"""Algorithm 2 as a Pallas TPU kernel.

    out[n] = sum_k w[k] * x[k, n]        (weights pre-normalized)

The stacked parameter matrix (K, N) streams through VMEM in (K, BN)
tiles; the weighted reduction over K is a (1, K) x (K, BN) matmul on
the MXU. BN = 2048 lanes (16 sublanes x 128) keeps the tile ~0.5 MB for
K <= 64 in f32 — comfortably inside the ~16 MB A VMEM budget while deep
enough to amortize the HBM->VMEM copy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _wavg_kernel(w_ref, x_ref, o_ref):
    # w: (1, K) f32, x: (K, BN), out: (1, BN)
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.dot(w, x, preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wavg_pallas(x, w, *, interpret: bool = False):
    """x: (K, N) stacked parameters; w: (K,) normalized weights -> (N,)."""
    k, n = x.shape
    assert n % BLOCK_N == 0, "ops.py pads N to BLOCK_N"
    grid = (n // BLOCK_N,)
    out = pl.pallas_call(
        _wavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),          # weights
            pl.BlockSpec((k, BLOCK_N), lambda i: (0, i)),    # param tile
        ],
        out_specs=pl.BlockSpec((1, BLOCK_N), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), x.dtype),
        interpret=interpret,
    )(w.reshape(1, k), x)
    return out[0]

from repro.kernels.wavg import ops, ref

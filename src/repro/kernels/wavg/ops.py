"""Jit'd wrapper: pads/reshapes arbitrary parameter tensors for the
wavg kernel and exposes the pytree-level Algorithm 2 entry point."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wavg.kernel import wavg_pallas, BLOCK_N
from repro.kernels.wavg.ref import wavg_ref

_INTERPRET = jax.default_backend() == "cpu"


def weighted_average(x, w, *, interpret: bool | None = None):
    """Weighted average over the leading (device) axis of one tensor.

    x: (K, ...) stacked parameter tensor; w: (K,) normalized weights.

    The flattened payload is zero-padded up to BLOCK_N for the kernel
    and the padded tail sliced off the (N_padded,) output before the
    reshape — exact at every block edge (n = 1, BLOCK_N, BLOCK_N + 1:
    tests/test_kernels.py). Also the entry point for the mesh-round hot
    path: `core.averaging.weighted_average_psum(impl="pallas")` calls
    this on the all-gathered flat payload, x = (K, N_total).
    """
    if interpret is None:
        interpret = _INTERPRET
    k = x.shape[0]
    flat = x.reshape(k, -1)
    n = flat.shape[1]
    pad = (-n) % BLOCK_N
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = wavg_pallas(flat, w.astype(jnp.float32), interpret=interpret)
    return out[:n].reshape(x.shape[1:])


def weighted_average_tree(tree, w, *, interpret: bool | None = None):
    """Algorithm 2 over a stacked parameter pytree."""
    return jax.tree.map(
        lambda x: weighted_average(x, w, interpret=interpret), tree)


__all__ = ["weighted_average", "weighted_average_tree", "wavg_ref"]

"""Pytree checkpointing: flat-key .npz payload + JSON manifest.

Round-resumable: the trainer state (params, optimizer moments, round
counter, scheduler cursor) round-trips exactly. No external deps.

bfloat16 leaves (the launch path's compute dtype) are stored as their
uint16 bit pattern with a key marker — np.savez writes ml_dtypes
arrays as raw void bytes that numpy cannot cast back, so the bit-level
view is the only exact round-trip.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np

try:  # ships with jax
    from ml_dtypes import bfloat16 as _BF16
except ImportError:  # pragma: no cover - jax always vendors ml_dtypes
    _BF16 = None

_SEP = "::"
_BF16_MARK = "__bf16__"


def _flatten(tree):
    flat = {}

    def mark(prefix, marker):
        flat[f"{prefix}{_SEP}{marker}" if prefix else marker] = np.zeros(0)

    def walk(prefix, node):
        if isinstance(node, dict):
            if not node:   # empty containers must round-trip (sgd opt state)
                mark(prefix, "__empty_dict__")
                return
            for k in sorted(node):
                walk(f"{prefix}{_SEP}{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            if not node:
                mark(prefix, "__empty_list__")
                return
            for i, v in enumerate(node):
                walk(f"{prefix}{_SEP}[{i}]", v)
        elif node is None:
            mark(prefix, "__none__")
        else:
            arr = np.asarray(node)
            if _BF16 is not None and arr.dtype == _BF16:
                flat[f"{prefix}{_SEP}{_BF16_MARK}"] = arr.view(np.uint16)
            else:
                flat[prefix] = arr

    walk("", tree)
    return flat


def _unflatten(flat):
    tree: dict = {}
    list_marker = re.compile(r"^\[(\d+)\]$")
    for key in sorted(flat):
        parts = key.split(_SEP)
        if parts[-1] == _BF16_MARK:
            parts = parts[:-1]
            value = flat[key].view(_BF16)
        elif parts[-1] == "__none__":
            parts = parts[:-1]
            value = None
        elif parts[-1] == "__empty_dict__":
            parts = parts[:-1]
            value = {}
        elif parts[-1] == "__empty_list__":
            parts = parts[:-1]
            value = []
        else:
            value = flat[key]
        if not parts or parts == [""]:   # whole tree is one empty container
            tree = value
            continue
        node = tree
        for i, part in enumerate(parts):
            last = i == len(parts) - 1
            if last:
                node[part] = value
            else:
                node = node.setdefault(part, {})
    # convert {"[0]": ..., "[1]": ...} dicts back to lists
    def fix(node):
        if isinstance(node, dict):
            keys = list(node)
            if keys and all(list_marker.match(k) for k in keys):
                return [fix(node[f"[{i}]"]) for i in range(len(keys))]
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(tree)


def save_checkpoint(directory: str, step: int, tree, *, metadata=None):
    os.makedirs(directory, exist_ok=True)
    host_tree = jax.device_get(tree)
    flat = _flatten(host_tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    tmp = path + ".tmp.npz"   # savez appends .npz unless already present
    np.savez(tmp, **flat)
    os.replace(tmp, path)
    manifest = {"step": step, "n_arrays": len(flat),
                "metadata": metadata or {}}
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return path


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int | None = None):
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat = {k: data[k] for k in data.files}
    manifest_path = os.path.join(directory, f"ckpt_{step:08d}.json")
    metadata = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            metadata = json.load(f).get("metadata", {})
    return _unflatten(flat), step, metadata

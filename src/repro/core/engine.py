"""Host-side training engine: drives communication rounds with device
scheduling, the wireless channel simulator, wall-clock accounting, and
periodic evaluation. This is the paper's experimental harness (Figs 3-6).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProtocolConfig
from repro.core import protocol, fedgan
from repro.core.channel import ChannelConfig, ChannelSimulator, round_wallclock
from repro.core.scheduling import SchedulerState, schedule_round


@dataclasses.dataclass
class RoundRecord:
    round: int
    wallclock_s: float
    cumulative_s: float
    metrics: dict
    fid: Optional[float] = None


class Trainer:
    """Runs the proposed protocol, FedGAN, or centralized training over a
    simulated device fleet. All model math is jitted; scheduling and
    channel timing are host-side numpy."""

    def __init__(self, spec: protocol.GanModelSpec, pcfg: ProtocolConfig,
                 init_fn: Callable, data_stacked, key, *,
                 algorithm: str = "proposed",
                 channel_cfg: Optional[ChannelConfig] = None,
                 disc_step_flops: float = 1e9, gen_step_flops: float = 1e9):
        self.spec, self.pcfg = spec, pcfg
        self.algorithm = algorithm
        self.key = key
        self.data = data_stacked
        self.n_devices = pcfg.n_devices
        self.channel = ChannelSimulator(channel_cfg or ChannelConfig(
            n_devices=pcfg.n_devices))
        self.sched = SchedulerState(
            policy=pcfg.scheduler, n_devices=pcfg.n_devices,
            ratio=pcfg.scheduling_ratio)
        self.rng = np.random.default_rng(0)
        self.disc_step_flops = disc_step_flops
        self.gen_step_flops = gen_step_flops

        if algorithm == "fedgan":
            self.state = fedgan.make_fedgan_state(key, init_fn, pcfg,
                                                  self.n_devices)
            self._round = jax.jit(
                lambda s, d, w, k: fedgan.fedgan_round(spec, pcfg, s, d, w, k))
        elif algorithm == "centralized":
            self.state = protocol.make_train_state(key, init_fn, pcfg, 1)
            pooled = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), data_stacked)
            self._pooled = pooled
            self._round = jax.jit(
                lambda s, d, w, k: protocol.centralized_step(spec, pcfg, s, d, k))
        else:
            self.state = protocol.make_train_state(key, init_fn, pcfg,
                                                   self.n_devices)
            self._round = jax.jit(
                lambda s, d, w, k: protocol.gan_round(spec, pcfg, s, d, w, k))

        self._disc_nparams = sum(
            int(x.size) for x in jax.tree_util.tree_leaves(self.state["disc"]))
        self._gen_nparams = sum(
            int(x.size) for x in jax.tree_util.tree_leaves(self.state["gen"]))
        self.history: list[RoundRecord] = []
        self._clock = 0.0

    # ------------------------------------------------------------------
    def run(self, n_rounds: int, *, eval_every: int = 0,
            fid_fn: Optional[Callable] = None, verbose: bool = False):
        for t in range(n_rounds):
            round_key = jax.random.fold_in(self.key, t)

            # Step 1: schedule + channel state
            rates = self.channel.uplink_rates(self.sched.n_scheduled)
            mask = schedule_round(self.sched, rates, self.rng)
            timing = self.channel.round_timing(
                mask=mask, disc_params=self._disc_nparams,
                gen_params=self._gen_nparams,
                disc_step_flops=self.disc_step_flops,
                gen_step_flops=self.gen_step_flops,
                n_d=self.pcfg.n_d, n_g=self.pcfg.n_g,
                fedgan=self.algorithm == "fedgan")
            active = mask & ~timing.stragglers
            weights = jnp.asarray(
                np.where(active, float(self.pcfg.sample_size), 0.0),
                dtype=jnp.float32)

            # Steps 2-5 (jitted)
            data = self._pooled if self.algorithm == "centralized" else self.data
            self.state, metrics = self._round(self.state, data, weights,
                                              round_key)

            wall = round_wallclock(timing, mask,
                                   schedule=self.pcfg.schedule,
                                   fedgan=self.algorithm == "fedgan")
            self._clock += wall
            fid = None
            if fid_fn is not None and eval_every and (t + 1) % eval_every == 0:
                fid = float(fid_fn(self.state["gen"],
                                   jax.random.fold_in(self.key, 10_000 + t)))
            rec = RoundRecord(t, wall, self._clock,
                              {k: float(v) for k, v in metrics.items()}, fid)
            self.history.append(rec)
            if verbose:
                msg = (f"round {t:4d}  t={self._clock:9.2f}s  "
                       f"D={rec.metrics.get('disc_objective', float('nan')):+.4f}")
                if fid is not None:
                    msg += f"  FID={fid:8.2f}"
                print(msg)
        return self.history

"""Training engine: drives communication rounds with device scheduling,
the wireless channel simulator, wall-clock accounting, and periodic
evaluation. This is the paper's experimental harness (Figs 3-6).

Two drivers:

  driver="fused" (default for the proposed protocol) — chunks of R
      rounds run through `protocol.gan_rounds_scan`: scheduling, channel
      timing, the model math, and wall-clock accounting are one XLA
      dispatch per chunk (donated state, no per-round host round-trip).
      Chunk boundaries fall on `eval_every` so FID evaluation interleaves
      exactly as in the host loop.
  driver="host" — the original per-round host loop over numpy
      scheduling/channel state. Retained as the EQUIVALENCE ORACLE: with
      a deterministic scheduler (or fading=False) the fused driver must
      reproduce its masks bitwise and params/metrics to float32
      round-off, which tests/test_driver_equivalence.py enforces.

FedGAN and centralized baselines always use the host loop (their round
costs are per-round host decisions and they don't need thousands of
cheap rounds).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProtocolConfig
from repro.core import protocol, fedgan
from repro.core.channel import ChannelConfig, ChannelSimulator, round_wallclock
from repro.core.jax_channel import JaxChannel
from repro.core.jax_scheduling import JaxScheduler
from repro.core.scheduling import SchedulerState, schedule_round


@dataclasses.dataclass
class RoundRecord:
    round: int
    wallclock_s: float
    cumulative_s: float
    metrics: dict
    fid: Optional[float] = None
    mask: Optional[np.ndarray] = None   # (K,) bool — scheduled devices


class Trainer:
    """Runs the proposed protocol, FedGAN, or centralized training over a
    simulated device fleet. All model math is jitted; the fused driver
    additionally folds scheduling + channel timing into the same
    dispatch, while the host driver keeps them in numpy."""

    def __init__(self, spec: protocol.GanModelSpec, pcfg: ProtocolConfig,
                 init_fn: Callable, data_stacked, key, *,
                 algorithm: str = "proposed",
                 channel_cfg: Optional[ChannelConfig] = None,
                 disc_step_flops: float = 1e9, gen_step_flops: float = 1e9,
                 driver: str = "fused"):
        self.spec, self.pcfg = spec, pcfg
        self.algorithm = algorithm
        self.key = key
        self.data = data_stacked
        self.n_devices = pcfg.n_devices
        channel_cfg = channel_cfg or ChannelConfig(n_devices=pcfg.n_devices)
        self.channel = ChannelSimulator(channel_cfg)
        self.sched = SchedulerState(
            policy=pcfg.scheduler, n_devices=pcfg.n_devices,
            ratio=pcfg.scheduling_ratio)
        self.rng = np.random.default_rng(0)
        self.disc_step_flops = disc_step_flops
        self.gen_step_flops = gen_step_flops
        if driver not in ("fused", "host"):
            raise ValueError(f"unknown driver {driver!r}")
        # only the proposed protocol has a fused scan path
        self.driver = driver if algorithm == "proposed" else "host"

        if algorithm == "fedgan":
            self.state = fedgan.make_fedgan_state(key, init_fn, pcfg,
                                                  self.n_devices)
            self._round = jax.jit(
                lambda s, d, w, k: fedgan.fedgan_round(spec, pcfg, s, d, w, k))
        elif algorithm == "centralized":
            self.state = protocol.make_train_state(key, init_fn, pcfg, 1)
            pooled = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), data_stacked)
            self._pooled = pooled
            self._round = jax.jit(
                lambda s, d, w, k: protocol.centralized_step(spec, pcfg, s, d, k))
        else:
            self.state = protocol.make_train_state(key, init_fn, pcfg,
                                                   self.n_devices)
            self._round = jax.jit(
                lambda s, d, w, k: protocol.gan_round(spec, pcfg, s, d, w, k))

        if self.driver == "fused":
            self.jax_channel = JaxChannel(channel_cfg)
            self.jax_sched = JaxScheduler(
                policy=pcfg.scheduler, n_devices=pcfg.n_devices,
                ratio=pcfg.scheduling_ratio)
            self._sched_carry = self.jax_sched.init_carry()
            self._chunk_fns: dict[int, Callable] = {}

        self._disc_nparams = protocol.count_params(self.state["disc"])
        self._gen_nparams = protocol.count_params(self.state["gen"])
        self.history: list[RoundRecord] = []
        self._clock = 0.0
        self._round_index = 0

    # ------------------------------------------------------------------
    def run(self, n_rounds: int, *, eval_every: int = 0,
            fid_fn: Optional[Callable] = None, verbose: bool = False):
        if self.driver == "fused":
            return self._run_fused(n_rounds, eval_every=eval_every,
                                   fid_fn=fid_fn, verbose=verbose)
        return self._run_host(n_rounds, eval_every=eval_every,
                              fid_fn=fid_fn, verbose=verbose)

    # ------------------------------------------------------------------
    # fused driver — R rounds per dispatch
    # ------------------------------------------------------------------
    def _chunk_fn(self, n: int):
        """Jitted `gan_rounds_scan` over a fixed chunk length n; the
        start round is traced so one compile serves every chunk of this
        length. State and scheduler carry are donated."""
        fn = self._chunk_fns.get(n)
        if fn is None:
            spec, pcfg = self.spec, self.pcfg

            def run_chunk(state, sched_carry, data, key, start_round):
                return protocol.gan_rounds_scan(
                    spec, pcfg, state, data, key, n,
                    channel=self.jax_channel, scheduler=self.jax_sched,
                    sched_carry=sched_carry, start_round=start_round,
                    disc_step_flops=self.disc_step_flops,
                    gen_step_flops=self.gen_step_flops)

            fn = jax.jit(run_chunk, donate_argnums=(0, 1))
            self._chunk_fns[n] = fn
        return fn

    def _eval_boundaries(self, n_rounds: int, eval_every: int,
                        have_fid: bool):
        """Chunk lengths whose boundaries land on the FID-eval rounds."""
        if not (have_fid and eval_every):
            return [n_rounds] if n_rounds else []
        chunks, done = [], 0
        start = self._round_index
        while done < n_rounds:
            # next multiple of eval_every past the current absolute round
            nxt = ((start + done) // eval_every + 1) * eval_every
            chunks.append(min(nxt - (start + done), n_rounds - done))
            done += chunks[-1]
        return chunks

    def _run_fused(self, n_rounds: int, *, eval_every: int,
                   fid_fn: Optional[Callable], verbose: bool):
        for chunk in self._eval_boundaries(n_rounds, eval_every,
                                           fid_fn is not None):
            start = self._round_index
            self.state, self._sched_carry, out = self._chunk_fn(chunk)(
                self.state, self._sched_carry, self.data, self.key,
                jnp.int32(start))
            metrics = {k: np.asarray(v) for k, v in out["metrics"].items()}
            walls = np.asarray(out["wallclock_s"])
            masks = np.asarray(out["mask"])
            for i in range(chunk):
                t = start + i
                self._clock += float(walls[i])
                fid = None
                if (fid_fn is not None and eval_every
                        and (t + 1) % eval_every == 0):
                    fid = float(fid_fn(self.state["gen"],
                                       jax.random.fold_in(self.key,
                                                          10_000 + t)))
                rec = RoundRecord(
                    t, float(walls[i]), self._clock,
                    {k: float(v[i]) for k, v in metrics.items()}, fid,
                    mask=masks[i])
                self.history.append(rec)
                if verbose:
                    self._print_record(rec)
            self._round_index += chunk
        return self.history

    # ------------------------------------------------------------------
    # host driver — one round per dispatch (the oracle)
    # ------------------------------------------------------------------
    def _run_host(self, n_rounds: int, *, eval_every: int,
                  fid_fn: Optional[Callable], verbose: bool):
        for _ in range(n_rounds):
            t = self._round_index

            # Step 1: schedule + channel state
            rates = self.channel.uplink_rates(self.sched.n_scheduled)
            mask = schedule_round(self.sched, rates, self.rng)
            timing = self.channel.round_timing(
                mask=mask, disc_params=self._disc_nparams,
                gen_params=self._gen_nparams,
                disc_step_flops=self.disc_step_flops,
                gen_step_flops=self.gen_step_flops,
                n_d=self.pcfg.n_d, n_g=self.pcfg.n_g,
                fedgan=self.algorithm == "fedgan")
            active = mask & ~timing.stragglers
            weights = jnp.asarray(
                np.where(active, float(self.pcfg.sample_size), 0.0),
                dtype=jnp.float32)

            # Steps 2-5 (jitted)
            round_key = jax.random.fold_in(self.key, t)
            data = self._pooled if self.algorithm == "centralized" else self.data
            self.state, metrics = self._round(self.state, data, weights,
                                              round_key)

            wall = round_wallclock(timing, mask,
                                   schedule=self.pcfg.schedule,
                                   fedgan=self.algorithm == "fedgan")
            self._clock += wall
            fid = None
            if fid_fn is not None and eval_every and (t + 1) % eval_every == 0:
                fid = float(fid_fn(self.state["gen"],
                                   jax.random.fold_in(self.key, 10_000 + t)))
            rec = RoundRecord(t, wall, self._clock,
                              {k: float(v) for k, v in metrics.items()}, fid,
                              mask=mask.copy())
            self.history.append(rec)
            self._round_index += 1
            if verbose:
                self._print_record(rec)
        return self.history

    # ------------------------------------------------------------------
    @staticmethod
    def _print_record(rec: RoundRecord):
        msg = (f"round {rec.round:4d}  t={rec.cumulative_s:9.2f}s  "
               f"D={rec.metrics.get('disc_objective', float('nan')):+.4f}")
        if rec.fid is not None:
            msg += f"  FID={rec.fid:8.2f}"
        print(msg)

"""Training engine: drives communication rounds with device scheduling,
the wireless channel simulator, wall-clock accounting, and periodic
evaluation. This is the paper's experimental harness (Figs 3-6).

The round-execution stack has THREE orthogonal axes — ALGORITHM x
LAYOUT x DRIVER — and the matrix is COMPLETE for every combination
that is meaningful:

                    layout="stacked"          layout="mesh"
  proposed       host + fused              host + fused
  fedgan         host + fused              host + fused
  centralized    host only                 — (no device structure)

EXECUTION LAYOUT — how the paper's K devices map onto hardware:

  layout="stacked" (default) — devices are a stacked leading axis on
      one logical device; vmap runs the local updates and the averaging
      is a weighted mean over the axis (GSPMD lowers it to the
      all-reduce when the axis is mesh-sharded through launch/steps.py).
  layout="mesh" — devices are mesh slices under `jax.shard_map` with
      explicit collectives (core.shard_round): local updates touch no
      collective, the averaging is one all-gather + the Pallas `wavg`
      kernel per round (both nets in ONE payload for FedGAN), and any
      server math is replicated shared-seed computation. Requires >= K
      addressable devices (pass `mesh=` or let the Trainer build a
      (K, tp) host mesh). With `tp > 1` the mesh is 2-D
      (device x model): each paper-worker slice is a TP group running
      Megatron column/row-parallel matmuls with in-slice collectives on
      the `model` axis (the spec must be built TP-aware, e.g.
      `models.gan.mlp_gan_spec(tp_axis="model")` /
      `make_backbone_spec(tp_axis="model")`), while scheduling, channel
      timing, uplink keying, and the Algorithm-2 reduction stay on the
      device axes — each TP rank averages just its parameter shard.
      State, checkpoints, and histories stay GLOBAL-shaped (shard_map
      splits/reassembles), so checkpoints interoperate across tp
      widths. tp > 1 requires layout="mesh" (stacked TP is the GSPMD
      path through launch/steps.py).

DRIVER — how rounds are dispatched:

  driver="fused" — chunks of R rounds run as ONE XLA dispatch
      (`protocol.rounds_scan` / `fedgan.fedgan_rounds_scan` on the
      stacked layout, `shard_round.shard_rounds_scan` /
      `shard_round.fedgan_shard_rounds_scan` on the mesh layout):
      scheduling, channel timing, the quantized uplink, the model math,
      and wall-clock accounting all inside one `lax.scan`, state
      donated. With a JITTABLE fid_fn, FID runs IN-SCAN via lax.cond; a
      non-traceable fid_fn falls back to eval-boundary chunking.
  driver="host" — one round per dispatch with numpy scheduling/channel
      state. On the stacked layout this is the original per-round loop,
      retained as the EQUIVALENCE ORACLE: the fused drivers (BOTH
      layouts, BOTH fused algorithms) must reproduce its masks bitwise
      and params/metrics to float32 round-off
      (tests/test_driver_equivalence.py). On the mesh layout it
      dispatches the algorithm's single-round shard_map entry per round
      — the baseline `benchmarks/driver_bench.py --layout mesh`
      measures fused speedup against.
  driver="auto" (default) — fused where supported, host otherwise.

The per-algorithm construction (state init, per-round host function,
stacked fused scan, and the mesh single-round/fused-scan entries) lives
in the `_ALGORITHMS` strategy table instead of `__init__` branching.
Unsupported combinations RAISE instead of silently degrading: the
centralized baseline has no fused path and no mesh layout (its round
has no scheduling/channel/device structure to fold), so requesting
either for it is a ValueError.

CHECKPOINT/RESUME: `save_checkpoint`/`restore` serialize the model
state together with `_round_index`, `_clock`, and the scheduler carry
through `repro.checkpoint`, so a resumed fused run (either layout)
continues masks, params, AND the wallclock curve exactly — every
per-round random draw is keyed from the root key and the absolute round
index. Host-driver resume is exact only for deterministic schedulers
with fading off (its numpy streams are not serialized).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProtocolConfig
from repro.core import protocol, fedgan, shard_round
from repro.core import faults as faults_lib
from repro.core.channel import ChannelConfig, ChannelSimulator, round_wallclock
from repro.core.faults import FaultConfig
from repro.core.jax_channel import JaxChannel
from repro.core.jax_scheduling import JaxScheduler
from repro.core.scheduling import SchedulerState, schedule_round
from repro.kernels.robust_avg import ROBUST_METHODS, RobustConfig


@dataclasses.dataclass(frozen=True)
class _Algorithm:
    """Strategy record: how one algorithm builds state, its per-round
    host function, (when fused-capable) its stacked rounds-scan, and
    (when mesh-capable) its shard_map single-round / fused-scan
    entries."""
    make_state: Callable          # (key, init_fn, pcfg, n_devices) -> state
    round_fn: Callable  # (spec, pcfg, faults, reducer) -> (s,d,w,k) -> (s, m)
    rounds_scan: Optional[Callable] = None   # unified stacked engine entry
    mesh_round: Optional[Callable] = None    # (spec, pcfg, mesh,
    #                                  device_axes=, tp_axis=, tp=)
    mesh_rounds_scan: Optional[Callable] = None  # fused mesh engine entry
    payload: Optional[Callable] = None  # state -> uplink payload tree (the
    #                                  free-rider stale-cache initializer)
    fedgan: bool = False
    pooled: bool = False          # centralized: pools the data shards

    @property
    def fused(self) -> bool:
        return self.rounds_scan is not None

    @property
    def mesh(self) -> bool:
        return self.mesh_round is not None


_ALGORITHMS = {
    "proposed": _Algorithm(
        make_state=protocol.make_train_state,
        round_fn=lambda spec, pcfg, faults, reducer: (
            lambda s, d, w, k: protocol.gan_round(
                spec, pcfg, s, d, w, k, faults=faults, reducer=reducer)),
        rounds_scan=protocol.gan_rounds_scan,
        mesh_round=shard_round.shard_map_round,
        mesh_rounds_scan=shard_round.shard_rounds_scan,
        payload=shard_round.PROPOSED_PAYLOAD),
    "fedgan": _Algorithm(
        make_state=fedgan.make_fedgan_state,
        round_fn=lambda spec, pcfg, faults, reducer: (
            lambda s, d, w, k: fedgan.fedgan_round(
                spec, pcfg, s, d, w, k, faults=faults, reducer=reducer)),
        rounds_scan=fedgan.fedgan_rounds_scan,
        mesh_round=shard_round.fedgan_shard_map_round,
        mesh_rounds_scan=shard_round.fedgan_shard_rounds_scan,
        payload=shard_round.FEDGAN_PAYLOAD,
        fedgan=True),
    "centralized": _Algorithm(
        make_state=lambda key, init_fn, pcfg, n: protocol.make_train_state(
            key, init_fn, pcfg, 1),
        round_fn=lambda spec, pcfg, faults, reducer: (
            lambda s, d, w, k: protocol.centralized_step(spec, pcfg, s, d, k)),
        pooled=True),
}

# Algorithms with a fused multi-round scan path (the unified engine).
FUSED_ALGORITHMS = tuple(name for name, a in _ALGORITHMS.items() if a.fused)
# Algorithms with a mesh (shard_map) execution layout.
MESH_ALGORITHMS = tuple(name for name, a in _ALGORITHMS.items() if a.mesh)
LAYOUTS = ("stacked", "mesh")
# Algorithm-2 collective implementations on the mesh layout
# (core/averaging.py): flat gather + wavg kernel ("pallas", the
# default), per-leaf psum ("jnp"), or the quantized-payload ring
# collective ("ring", kernels/ring_wavg).
MESH_AVG_IMPLS = ("pallas", "jnp", "ring")


def mesh_algorithm(name: str) -> _Algorithm:
    """The strategy record for a mesh-capable algorithm — the ONE
    registry the launch layer (launch/steps.py, launch/train.py) reuses
    for state init and the fused mesh scan, so adding an algorithm here
    reaches every layer without parallel per-algorithm tables."""
    algo = _ALGORITHMS.get(name)
    if algo is None or not algo.mesh:
        raise ValueError(f"layout='mesh' supports algorithms "
                         f"{MESH_ALGORITHMS} (got {name!r})")
    return algo


@dataclasses.dataclass
class RoundRecord:
    round: int
    wallclock_s: float
    cumulative_s: float
    metrics: dict
    fid: Optional[float] = None
    mask: Optional[np.ndarray] = None   # (K,) bool — scheduled devices


class Trainer:
    """Runs the proposed protocol, FedGAN, or centralized training over a
    simulated device fleet. All model math is jitted; the fused driver
    additionally folds scheduling + channel timing into the same
    dispatch, while the host driver keeps them in numpy. See the module
    docstring for the algorithm x layout x driver matrix."""

    def __init__(self, spec: protocol.GanModelSpec, pcfg: ProtocolConfig,
                 init_fn: Callable, data_stacked, key, *,
                 algorithm: str = "proposed",
                 channel_cfg: Optional[ChannelConfig] = None,
                 disc_step_flops: float = 1e9, gen_step_flops: float = 1e9,
                 driver: str = "auto", layout: str = "stacked",
                 mesh=None, device_axes=("data",), tp: int = 1,
                 avg_impl: str = "pallas",
                 faults: Optional[FaultConfig] = None, reducer=None,
                 partition: Optional[str] = None, labels=None,
                 partition_alpha: float = 0.5, partition_seed: int = 0):
        if algorithm not in _ALGORITHMS:
            raise ValueError(f"unknown algorithm {algorithm!r} "
                             f"(have {tuple(_ALGORITHMS)})")
        algo = _ALGORITHMS[algorithm]
        if layout not in LAYOUTS:
            raise ValueError(f"unknown layout {layout!r} (have {LAYOUTS})")
        if layout == "mesh" and not algo.mesh:
            raise ValueError(
                f"layout='mesh' is not supported for algorithm "
                f"{algorithm!r} (mesh algorithms: {MESH_ALGORITHMS}); "
                f"use layout='stacked'")
        if tp < 1:
            raise ValueError(f"tp must be >= 1 (got {tp})")
        if tp > 1 and layout != "mesh":
            raise ValueError(
                f"tp={tp} requires layout='mesh' (in-slice tensor "
                f"parallelism is the 2-D shard_map engine; on the "
                f"stacked layout TP comes from GSPMD through "
                f"launch/steps.py)")
        # The spec's TP-awareness must match the engine's: a dense spec
        # consumes sharded params shape-consistently but never psums
        # the partial products — silently wrong, so refuse up front.
        spec_tp_axis = getattr(spec, "tp_axis", None)
        want_tp_axis = "model" if tp > 1 else None
        if layout == "mesh" and spec_tp_axis != want_tp_axis:
            raise ValueError(
                f"tp={tp} needs a spec built with "
                f"tp_axis={want_tp_axis!r}, got tp_axis="
                f"{spec_tp_axis!r} — rebuild it (e.g. "
                f"make_backbone_spec(tp_axis=...) / "
                f"mlp_gan_spec(tp_axis=...))")
        if layout != "mesh" and spec_tp_axis is not None:
            raise ValueError(
                f"spec was built with tp_axis={spec_tp_axis!r} (in-slice "
                f"collectives) but layout={layout!r} runs no shard_map; "
                f"rebuild the spec with tp_axis=None")
        if driver not in ("auto", "fused", "host"):
            raise ValueError(f"unknown driver {driver!r}")
        if driver == "fused" and not algo.fused:
            raise ValueError(
                f"driver='fused' is not supported for algorithm "
                f"{algorithm!r} (fused algorithms: {FUSED_ALGORITHMS}); "
                f"use driver='host' or 'auto'")
        if driver == "auto":
            driver = "fused" if algo.fused else "host"

        # Hostile-worker regime (core/faults.py + kernels/robust_avg):
        # `reducer` accepts a method name ("mean" = plain weighted
        # average), or a full RobustConfig for non-default parameters.
        if isinstance(reducer, str):
            reducer = None if reducer == "mean" else RobustConfig(
                method=reducer)
        if reducer is not None and not isinstance(reducer, RobustConfig):
            raise ValueError(
                f"reducer must be 'mean', one of {ROBUST_METHODS}, or a "
                f"RobustConfig (got {reducer!r})")
        if algo.payload is None and (faults is not None
                                     or reducer is not None):
            raise ValueError(
                f"faults/reducer are not supported for algorithm "
                f"{algorithm!r} (no device uploads to corrupt or "
                f"robustly aggregate)")
        if faults is not None and faults.n_devices != pcfg.n_devices:
            raise ValueError(
                f"faults.n_devices={faults.n_devices} must match "
                f"pcfg.n_devices={pcfg.n_devices}")
        # One definition of the tp x faults/robust contract — shared
        # with the mesh round builders and launch/steps.py.
        shard_round.check_faults_tp(faults, reducer,
                                    "model" if tp > 1 else None, tp)
        if avg_impl not in MESH_AVG_IMPLS:
            raise ValueError(f"unknown avg_impl {avg_impl!r} "
                             f"(have {MESH_AVG_IMPLS})")
        if avg_impl != "pallas" and layout != "mesh":
            raise ValueError(
                f"avg_impl={avg_impl!r} selects the mesh layout's "
                f"Algorithm-2 collective; layout={layout!r} has no "
                f"explicit collective (use layout='mesh' or the default "
                f"avg_impl='pallas')")
        shard_round.check_ring_support(avg_impl, device_axes,
                                       "model" if tp > 1 else None, tp,
                                       faults, reducer)
        self.avg_impl = avg_impl
        self.faults, self.reducer = faults, reducer
        self._fault_prog = faults_lib.fault_program(faults)

        # Dormant-data wiring: partition a FLAT dataset into per-device
        # shards (data/partition.py) so non-IID splits compose with
        # faults. `partition=None` keeps the pre-sharded contract.
        if partition is not None:
            if not hasattr(data_stacked, "shape"):
                raise ValueError(
                    "partition=... expects a single flat data array "
                    "(N, ...); pre-shard pytree datasets yourself")
            from repro.data.partition import partition as partition_fn
            data_stacked = jnp.asarray(partition_fn(
                np.asarray(data_stacked), pcfg.n_devices, labels=labels,
                kind=partition, alpha=partition_alpha,
                seed=partition_seed))

        self.spec, self.pcfg = spec, pcfg
        self.algorithm, self._algo = algorithm, algo
        self.driver, self.layout = driver, layout
        self.key = key
        self.data = data_stacked
        self.n_devices = pcfg.n_devices
        channel_cfg = channel_cfg or ChannelConfig(n_devices=pcfg.n_devices)
        self.channel = ChannelSimulator(channel_cfg)
        self.sched = SchedulerState(
            policy=pcfg.scheduler, n_devices=pcfg.n_devices,
            ratio=pcfg.scheduling_ratio)
        self.rng = np.random.default_rng(0)
        self.disc_step_flops = disc_step_flops
        self.gen_step_flops = gen_step_flops

        self.state = algo.make_state(key, init_fn, pcfg, self.n_devices)
        # Free-rider stale-upload cache: part of the state tree, so it
        # rides the scan carry / mesh replication / checkpoints like any
        # other state entry (resume under faults is exact).
        self.state = faults_lib.attach_fault_state(self.state, faults,
                                                   algo.payload)
        if algo.pooled:
            self._pooled = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), data_stacked)

        self.device_axes = device_axes
        self.tp = tp
        self.tp_axis = "model" if tp > 1 else None
        self.mesh = None
        if layout == "mesh":
            if mesh is None:
                from repro.launch.mesh import make_host_mesh
                mesh = make_host_mesh(pcfg.n_devices, tp)
            else:
                from repro.launch.mesh import tp_mesh_error
                err = tp_mesh_error(mesh, tp)
                if err:
                    raise ValueError(err)
            self.mesh = mesh
            self._round = algo.mesh_round(spec, pcfg, mesh,
                                          device_axes=device_axes,
                                          avg_impl=avg_impl,
                                          tp_axis=self.tp_axis, tp=tp,
                                          faults=faults, robust=reducer)
        else:
            self._round = jax.jit(algo.round_fn(spec, pcfg, faults,
                                                reducer))

        if self.driver == "fused":
            self.jax_channel = JaxChannel(channel_cfg)
            self.jax_sched = JaxScheduler(
                policy=pcfg.scheduler, n_devices=pcfg.n_devices,
                ratio=pcfg.scheduling_ratio)
            self._sched_carry = self.jax_sched.init_carry()
            self._chunk_fns: dict[tuple, tuple] = {}

        self._disc_nparams = protocol.count_params(self.state["disc"])
        self._gen_nparams = protocol.count_params(self.state["gen"])
        # Actual uplink payload at the protocol's quantization width
        # (both nets for FedGAN) — drives the channel's upload timing.
        self._uplink_bits = protocol.uplink_payload_bits(
            self.state, pcfg, fedgan=algo.fedgan)
        self.history: list[RoundRecord] = []
        self._clock = 0.0
        self._round_index = 0

    # ------------------------------------------------------------------
    def run(self, n_rounds: int, *, eval_every: int = 0,
            fid_fn: Optional[Callable] = None, verbose: bool = False):
        if self.driver == "fused":
            return self._run_fused(n_rounds, eval_every=eval_every,
                                   fid_fn=fid_fn, verbose=verbose)
        return self._run_host(n_rounds, eval_every=eval_every,
                              fid_fn=fid_fn, verbose=verbose)

    # ------------------------------------------------------------------
    # fused driver — R rounds per dispatch (both layouts)
    # ------------------------------------------------------------------
    def _chunk_fn(self, n: int, eval_every: int = 0,
                  fid_fn: Optional[Callable] = None):
        """Chunk function over a fixed length n, per layout: the jitted
        stacked `rounds_scan` or the algorithm's mesh rounds-scan, with
        the signature (state, sched_carry, data, key, start_round) and
        donated state/carry. The start round is traced, so one compile
        serves every chunk of this length. With eval_every > 0 the
        (jittable) fid_fn is folded into the scan via lax.cond, so FID
        rounds need no chunk boundary."""
        cache_key = (n, eval_every)
        entry = self._chunk_fns.get(cache_key)
        # The cache holds a strong reference to the fid_fn each chunk
        # closed over, so a different (even same-id after gc) fid_fn
        # can never silently reuse a stale compiled closure.
        if entry is not None and (not eval_every or entry[0] is fid_fn):
            return entry[1]
        spec, pcfg = self.spec, self.pcfg

        if self.layout == "mesh":
            eval_fn = None
            if eval_every:
                eval_fn = lambda gen, t, key: fid_fn(
                    gen, jax.random.fold_in(key, 10_000 + t))
            fn = self._algo.mesh_rounds_scan(
                spec, pcfg, self.mesh, n,
                channel=self.jax_channel, scheduler=self.jax_sched,
                device_axes=self.device_axes,
                disc_step_flops=self.disc_step_flops,
                gen_step_flops=self.gen_step_flops,
                uplink_bits=self._uplink_bits,
                avg_impl=self.avg_impl,
                eval_fn=eval_fn, eval_every=eval_every,
                tp_axis=self.tp_axis, tp=self.tp,
                faults=self.faults, robust=self.reducer)
        else:
            scan = self._algo.rounds_scan

            def run_chunk(state, sched_carry, data, key, start_round):
                eval_fn = None
                if eval_every:
                    eval_fn = lambda gen, t: fid_fn(
                        gen, jax.random.fold_in(key, 10_000 + t))
                return scan(
                    spec, pcfg, state, data, key, n,
                    channel=self.jax_channel, scheduler=self.jax_sched,
                    sched_carry=sched_carry, start_round=start_round,
                    disc_step_flops=self.disc_step_flops,
                    gen_step_flops=self.gen_step_flops,
                    uplink_bits=self._uplink_bits,
                    eval_fn=eval_fn, eval_every=eval_every,
                    faults=self.faults, reducer=self.reducer)

            fn = jax.jit(run_chunk, donate_argnums=(0, 1))
        self._chunk_fns[cache_key] = (fid_fn if eval_every else None, fn)
        return fn

    def _fid_jittable(self, fid_fn) -> bool:
        """True when fid_fn traces (pure jnp), so it can run in-scan;
        numpy-based fid_fns fall back to eval-boundary chunking."""
        try:
            jax.eval_shape(fid_fn, self.state["gen"], self.key)
            return True
        except Exception:
            return False

    def _eval_boundaries(self, n_rounds: int, eval_every: int,
                        have_fid: bool):
        """Chunk lengths whose boundaries land on the FID-eval rounds
        (host-eval fallback for non-jittable fid_fns)."""
        if not (have_fid and eval_every):
            return [n_rounds] if n_rounds else []
        chunks, done = [], 0
        start = self._round_index
        while done < n_rounds:
            # next multiple of eval_every past the current absolute round
            nxt = ((start + done) // eval_every + 1) * eval_every
            chunks.append(min(nxt - (start + done), n_rounds - done))
            done += chunks[-1]
        return chunks

    def _run_fused(self, n_rounds: int, *, eval_every: int,
                   fid_fn: Optional[Callable], verbose: bool):
        in_scan_fid = bool(fid_fn is not None and eval_every
                           and self._fid_jittable(fid_fn))
        if in_scan_fid:
            chunks = [n_rounds] if n_rounds else []
        else:
            chunks = self._eval_boundaries(n_rounds, eval_every,
                                           fid_fn is not None)
        for chunk in chunks:
            start = self._round_index
            fn = self._chunk_fn(chunk, eval_every if in_scan_fid else 0,
                                fid_fn if in_scan_fid else None)
            self.state, self._sched_carry, out = fn(
                self.state, self._sched_carry, self.data, self.key,
                jnp.int32(start))
            metrics = {k: np.asarray(v) for k, v in out["metrics"].items()}
            walls = np.asarray(out["wallclock_s"])
            masks = np.asarray(out["mask"])
            fids = np.asarray(out["fid"]) if "fid" in out else None
            fid_evals = (np.asarray(out["fid_eval"])
                         if "fid_eval" in out else None)
            for i in range(chunk):
                t = start + i
                self._clock += float(walls[i])
                fid = None
                if fids is not None:
                    # explicit eval mask: a NaN FID on an eval round is
                    # reported as NaN, exactly like the host loop
                    if fid_evals[i]:
                        fid = float(fids[i])
                elif (fid_fn is not None and eval_every
                        and (t + 1) % eval_every == 0):
                    fid = float(fid_fn(self.state["gen"],
                                       jax.random.fold_in(self.key,
                                                          10_000 + t)))
                rec = RoundRecord(
                    t, float(walls[i]), self._clock,
                    {k: float(v[i]) for k, v in metrics.items()}, fid,
                    mask=masks[i])
                self.history.append(rec)
                if verbose:
                    self._print_record(rec)
            self._round_index += chunk
        return self.history

    # ------------------------------------------------------------------
    # host driver — one round per dispatch (the oracle)
    # ------------------------------------------------------------------
    def _run_host(self, n_rounds: int, *, eval_every: int,
                  fid_fn: Optional[Callable], verbose: bool):
        for _ in range(n_rounds):
            t = self._round_index
            round_key = jax.random.fold_in(self.key, t)

            # Step 1: schedule + channel state. Fault dropout knocks
            # scheduled devices out BEFORE timing, realized from the
            # SAME round key as the fused drivers so masks stay bitwise
            # identical across every engine (core/faults.py).
            rates = self.channel.uplink_rates(self.sched.n_scheduled)
            mask = schedule_round(self.sched, rates, self.rng)
            compute_mult = None
            if self._fault_prog is not None:
                mask = mask & ~self._fault_prog.dropout_mask_np(round_key)
                compute_mult = self._fault_prog.compute_mult_np
            timing = self.channel.round_timing(
                mask=mask, disc_params=self._disc_nparams,
                gen_params=self._gen_nparams,
                disc_step_flops=self.disc_step_flops,
                gen_step_flops=self.gen_step_flops,
                n_d=self.pcfg.n_d, n_g=self.pcfg.n_g,
                fedgan=self._algo.fedgan,
                uplink_bits=self._uplink_bits,
                compute_mult=compute_mult)
            active = mask & ~timing.stragglers
            weights = jnp.asarray(
                np.where(active, float(self.pcfg.sample_size), 0.0),
                dtype=jnp.float32)

            # Steps 2-5 (jitted)
            data = self._pooled if self._algo.pooled else self.data
            self.state, metrics = self._round(self.state, data, weights,
                                              round_key)

            wall = round_wallclock(timing, mask,
                                   schedule=self.pcfg.schedule,
                                   fedgan=self._algo.fedgan)
            self._clock += wall
            fid = None
            if fid_fn is not None and eval_every and (t + 1) % eval_every == 0:
                fid = float(fid_fn(self.state["gen"],
                                   jax.random.fold_in(self.key, 10_000 + t)))
            rec = RoundRecord(t, wall, self._clock,
                              {k: float(v) for k, v in metrics.items()}, fid,
                              mask=mask.copy())
            self.history.append(rec)
            self._round_index += 1
            if verbose:
                self._print_record(rec)
        return self.history

    # ------------------------------------------------------------------
    # checkpoint / resume
    # ------------------------------------------------------------------
    def save_checkpoint(self, directory: str):
        """Serialize model state + round index + wallclock + scheduler
        carry, so `restore` continues the run — including the wallclock
        curve — exactly (fused drivers; see module docstring for the
        host-driver caveat)."""
        from repro.checkpoint import save_checkpoint
        carry = (jax.device_get(self._sched_carry)
                 if self.driver == "fused" else
                 {"rr_cursor": np.int32(self.sched.rr_cursor),
                  # native f64: the numpy EWMA stream must resume exactly
                  "ewma_rate": np.asarray(self.sched.ewma_rate)})
        tree = {"state": self.state,
                "trainer": {"round_index": np.int64(self._round_index),
                            "clock": np.float64(self._clock),
                            "sched_carry": carry}}
        return save_checkpoint(
            directory, self._round_index, tree,
            metadata={"algorithm": self.algorithm, "layout": self.layout,
                      "driver": self.driver})

    def restore(self, directory: str, step: Optional[int] = None):
        """Load a checkpoint written by `save_checkpoint` (latest by
        default) and position the trainer to continue from it."""
        from repro.checkpoint import load_checkpoint
        tree, step, _ = load_checkpoint(directory, step)
        self.state = jax.tree.map(
            lambda ref, x: jnp.asarray(x, getattr(ref, "dtype", None)),
            self.state, tree["state"])
        extra = tree["trainer"]
        self._round_index = int(extra["round_index"])
        self._clock = float(extra["clock"])
        carry = extra["sched_carry"]
        if self.driver == "fused":
            self._sched_carry = {
                "rr_cursor": jnp.int32(carry["rr_cursor"]),
                "ewma_rate": jnp.asarray(carry["ewma_rate"], jnp.float32)}
        else:
            self.sched.rr_cursor = int(carry["rr_cursor"])
            self.sched.ewma_rate = np.asarray(carry["ewma_rate"],
                                              np.float64)
        return step

    # ------------------------------------------------------------------
    @staticmethod
    def _print_record(rec: RoundRecord):
        msg = (f"round {rec.round:4d}  t={rec.cumulative_s:9.2f}s  "
               f"D={rec.metrics.get('disc_objective', float('nan')):+.4f}")
        if rec.fid is not None:
            msg += f"  FID={rec.fid:8.2f}"
        print(msg)

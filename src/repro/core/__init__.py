"""The paper's primary contribution: the distributed GAN training
protocol (device discriminators + server generator, Algorithms 1-3, two
update schedules, device scheduling, wireless channel accounting)."""
from repro.core.protocol import (
    GanModelSpec,
    gan_round,
    gan_rounds_scan,
    device_update,
    server_update,
    centralized_step,
    make_train_state,
)
from repro.core.fedgan import fedgan_round, make_fedgan_state
from repro.core.averaging import (
    weighted_average,
    weighted_average_psum,
    broadcast_like,
)
from repro.core import losses, quantize
from repro.core.scheduling import SchedulerState, schedule_round
from repro.core.channel import (
    ChannelConfig,
    ChannelSimulator,
    round_wallclock,
)
from repro.core.jax_channel import JaxChannel
from repro.core.jax_scheduling import JaxScheduler, schedule_step
from repro.core.engine import Trainer

"""Device scheduling (paper Step 1 / Section IV Fig. 6).

The server selects S ⊆ K devices each round. Implemented policies:

  all           every device, every round
  round_robin   a rotating window of ceil(ratio*K) devices
  best_channel  the ceil(ratio*K) devices with the best instantaneous
                channel (what Fig. 6 uses: "devices with the best channels")
  prop_fair     proportional fair: rank by instantaneous rate divided by
                an exponentially-averaged historical rate
  random        uniform random subset (ablation)

All policies are host-side (numpy) — they produce a boolean mask that
feeds the jitted round step as the weight vector. Stragglers (footnote 1)
are excluded downstream by the channel simulator.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass
class SchedulerState:
    policy: str
    n_devices: int
    ratio: float = 1.0
    rr_cursor: int = 0
    ewma_rate: np.ndarray | None = None   # for prop_fair
    ewma_alpha: float = 0.2

    def __post_init__(self):
        if self.ewma_rate is None:
            self.ewma_rate = np.ones(self.n_devices)

    @property
    def n_scheduled(self) -> int:
        return max(1, math.ceil(self.ratio * self.n_devices))


def schedule_round(state: SchedulerState, rates: np.ndarray,
                   rng: np.random.Generator) -> np.ndarray:
    """rates: (K,) instantaneous uplink rates from the channel simulator.
    Returns boolean mask (K,) of scheduled devices and advances state."""
    k, n = state.n_devices, state.n_scheduled
    mask = np.zeros(k, dtype=bool)
    if state.policy == "all":
        mask[:] = True
    elif state.policy == "round_robin":
        idx = (state.rr_cursor + np.arange(n)) % k
        mask[idx] = True
        state.rr_cursor = (state.rr_cursor + n) % k
    elif state.policy == "best_channel":
        mask[np.argsort(rates)[-n:]] = True
    elif state.policy == "prop_fair":
        priority = rates / np.maximum(state.ewma_rate, 1e-12)
        mask[np.argsort(priority)[-n:]] = True
    elif state.policy == "random":
        mask[rng.choice(k, size=n, replace=False)] = True
    else:
        raise ValueError(f"unknown scheduling policy {state.policy!r}")

    served = np.where(mask, rates, 0.0)
    state.ewma_rate = ((1 - state.ewma_alpha) * state.ewma_rate
                       + state.ewma_alpha * served)
    return mask

"""Wireless system simulator (paper Section IV settings).

Small cell of radius 300 m, server at the center, K devices uniformly
placed. Path loss 128.1 + 37.6 log10(d_km) dB, noise PSD -174 dBm/Hz,
device Tx 24 dBm, server Tx 46 dBm, 10 MHz bandwidth, 16 bits per
parameter. Per-round Rayleigh fading gives rate variability; uploads
that exceed the round deadline mark the device a straggler (footnote 1).

This module accounts *wall-clock time* per communication round for both
proposed schedules and for FedGAN — the x-axis of the paper's figures.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ChannelConfig:
    n_devices: int = 10
    cell_radius_m: float = 300.0
    bandwidth_hz: float = 10e6
    noise_psd_dbm_hz: float = -174.0
    device_tx_dbm: float = 24.0
    server_tx_dbm: float = 46.0
    bits_per_param: int = 16
    # compute-speed constants (device vs server), FLOP/s
    device_flops: float = 1e12
    server_flops: float = 10e12
    fading: bool = True
    straggler_deadline_s: float = float("inf")
    seed: int = 0


@dataclasses.dataclass
class RoundTiming:
    compute_dev_s: np.ndarray      # (K,) local discriminator compute
    upload_s: np.ndarray           # (K,) local model upload
    compute_srv_s: float           # generator update
    broadcast_s: float             # global model broadcast
    stragglers: np.ndarray         # (K,) bool — missed the deadline


class ChannelSimulator:
    def __init__(self, cfg: ChannelConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # uniform placement in the disc (radius via sqrt for uniform density)
        r = cfg.cell_radius_m * np.sqrt(rng.uniform(0.05, 1.0, cfg.n_devices))
        self.dist_km = r / 1000.0
        self.rng = rng

    def path_loss_db(self):
        return 128.1 + 37.6 * np.log10(self.dist_km)

    def uplink_rates(self, n_scheduled: int) -> np.ndarray:
        """(K,) bits/s if scheduled now, equal OFDMA split of the band."""
        cfg = self.cfg
        bw = cfg.bandwidth_hz / max(n_scheduled, 1)
        noise_w = 10 ** ((cfg.noise_psd_dbm_hz - 30) / 10) * bw
        tx_w = 10 ** ((cfg.device_tx_dbm - 30) / 10)
        gain = 10 ** (-self.path_loss_db() / 10)
        if cfg.fading:
            gain = gain * self.rng.exponential(1.0, cfg.n_devices)
        snr = tx_w * gain / noise_w
        return bw * np.log2(1.0 + snr)

    def downlink_rate(self) -> float:
        """Broadcast rate, limited by the worst scheduled device."""
        cfg = self.cfg
        noise_w = 10 ** ((cfg.noise_psd_dbm_hz - 30) / 10) * cfg.bandwidth_hz
        tx_w = 10 ** ((cfg.server_tx_dbm - 30) / 10)
        gain = 10 ** (-self.path_loss_db() / 10)
        snr = tx_w * gain / noise_w
        return float(cfg.bandwidth_hz * np.min(np.log2(1.0 + snr)))

    # ------------------------------------------------------------------
    def round_timing(self, *, mask: np.ndarray, disc_params: int,
                     gen_params: int, disc_step_flops: float,
                     gen_step_flops: float, n_d: int, n_g: int,
                     fedgan: bool = False,
                     uplink_bits: float | None = None,
                     compute_mult: np.ndarray | None = None) -> RoundTiming:
        """Wall-clock pieces of one communication round.

        uplink_bits: total per-device upload payload in bits (e.g.
        `quantize.tree_bits` at the protocol's quantization width);
        None falls back to `bits_per_param` x the uploaded param count.
        compute_mult: optional (K,) per-device local-compute multiplier
        (core/faults.py — stragglers > 1, free-riders replaying stale
        uploads spend 0 compute).
        """
        cfg = self.cfg
        rates = self.uplink_rates(int(mask.sum()))
        up_bits = uplink_bits if uplink_bits is not None else (
            cfg.bits_per_param * (
                disc_params + gen_params if fedgan else disc_params))
        upload = np.where(mask, up_bits / np.maximum(rates, 1.0), 0.0)
        dev_flops = n_d * disc_step_flops + (n_g * gen_step_flops if fedgan else 0.0)
        compute_dev = np.where(mask, dev_flops / cfg.device_flops, 0.0)
        if compute_mult is not None:
            compute_dev = compute_dev * np.asarray(compute_mult, np.float64)
        compute_srv = 0.0 if fedgan else n_g * gen_step_flops / cfg.server_flops
        down_bits = cfg.bits_per_param * (disc_params + gen_params)
        broadcast = down_bits / self.downlink_rate()
        stragglers = mask & (upload + compute_dev > cfg.straggler_deadline_s)
        return RoundTiming(compute_dev, upload, compute_srv, broadcast,
                           stragglers)


def round_wallclock(t: RoundTiming, mask: np.ndarray, *, schedule: str,
                    fedgan: bool = False) -> float:
    """Fig. 1 / Fig. 2 composition of one round's wall-clock time."""
    active = mask & ~t.stragglers
    if not active.any():
        return float(t.broadcast_s)
    if fedgan:
        # FedGAN: local G+D compute, upload both, average (negligible), bcast
        return float(np.max((t.compute_dev_s + t.upload_s)[active])
                     + t.broadcast_s)
    if schedule == "parallel":
        # device compute overlaps server's generator compute (Fig. 1)
        dev_phase = np.max(t.compute_dev_s[active])
        return float(max(dev_phase, t.compute_srv_s)
                     + np.max(t.upload_s[active]) + t.broadcast_s)
    if schedule == "serial":
        # devices first; disc broadcast overlaps generator compute (Fig. 2)
        dev_phase = np.max((t.compute_dev_s + t.upload_s)[active])
        return float(dev_phase + max(t.compute_srv_s, t.broadcast_s * 0.5)
                     + t.broadcast_s * 0.5)
    raise ValueError(schedule)

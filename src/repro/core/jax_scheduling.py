"""Pure-JAX twin of `core.scheduling` (paper Step 1 / Fig. 6).

Same five policies, expressed as a jittable step whose mutable pieces —
the round-robin cursor and the proportional-fair EWMA rates — travel in
an explicit scan carry instead of a host-side dataclass, so the fused
multi-round driver (`protocol.gan_rounds_scan`) can run thousands of
scheduling decisions inside one `lax.scan` without a host round-trip.

Equivalence contract with the numpy twin (tested in
tests/test_driver_equivalence.py):

  * `all`, `round_robin`, `best_channel`, `prop_fair` select the SAME
    device sets as `scheduling.schedule_round` under identical rates
    (ties broken by ascending argsort position, which both argsorts
    agree on for distinct values), including cursor wrap-around and the
    EWMA evolution.
  * `random` matches in distribution only — `jax.random` and
    `numpy.random.Generator` are different streams.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class JaxScheduler:
    """Static (trace-time) scheduling configuration.

    The per-round mutable state lives in the carry from `init_carry`:
    {"rr_cursor": int32 scalar, "ewma_rate": float32 (K,)}.
    """
    policy: str
    n_devices: int
    ratio: float = 1.0
    ewma_alpha: float = 0.2

    @property
    def n_scheduled(self) -> int:
        return max(1, math.ceil(self.ratio * self.n_devices))

    def init_carry(self):
        return {"rr_cursor": jnp.int32(0),
                "ewma_rate": jnp.ones(self.n_devices, jnp.float32)}


def _top_n_mask(scores, n: int):
    """Boolean mask of the n highest-scoring devices (argsort tail,
    matching the numpy twin's `argsort(x)[-n:]`)."""
    k = scores.shape[0]
    idx = jnp.argsort(scores)[k - n:]
    return jnp.zeros(k, dtype=bool).at[idx].set(True)


def schedule_step(sched: JaxScheduler, carry, rates, key):
    """One scheduling decision: (carry, rates, key) -> (mask, new_carry).

    rates: (K,) instantaneous uplink rates. The policy string is static,
    so each policy traces to its own branch-free program.
    """
    k, n = sched.n_devices, sched.n_scheduled
    cursor = carry["rr_cursor"]
    if sched.policy == "all":
        mask = jnp.ones(k, dtype=bool)
    elif sched.policy == "round_robin":
        idx = (cursor + jnp.arange(n)) % k
        mask = jnp.zeros(k, dtype=bool).at[idx].set(True)
        cursor = ((cursor + n) % k).astype(jnp.int32)
    elif sched.policy == "best_channel":
        mask = _top_n_mask(rates, n)
    elif sched.policy == "prop_fair":
        priority = rates / jnp.maximum(carry["ewma_rate"], 1e-12)
        mask = _top_n_mask(priority, n)
    elif sched.policy == "random":
        perm = jax.random.permutation(key, k)
        mask = jnp.zeros(k, dtype=bool).at[perm[:n]].set(True)
    else:
        raise ValueError(f"unknown scheduling policy {sched.policy!r}")

    served = jnp.where(mask, rates, 0.0).astype(jnp.float32)
    ewma = ((1.0 - sched.ewma_alpha) * carry["ewma_rate"]
            + sched.ewma_alpha * served)
    return mask, {"rr_cursor": cursor, "ewma_rate": ewma}

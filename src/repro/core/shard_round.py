"""Manual-collective (shard_map) implementation of one protocol round.

The pjit path (core.protocol.gan_round) expresses the paper's K devices
as a stacked leading axis and lets GSPMD insert the averaging
all-reduce. This module expresses the SAME round with explicit
`jax.lax.psum` collectives under `jax.shard_map`: every mesh slice IS a
device — local discriminator steps touch no collective (Algorithm 1 is
embarrassingly parallel), Algorithm 2 is a weighted psum, and the server
update is replicated shared-seed computation (the paper's single server
maps to identical per-slice generator math — no gradient collective is
needed because the shared noise makes every slice compute the same
update).

Used by tests to prove the two paths agree bit-for-bit on a host mesh,
and by the §Perf hillclimb to compare collective schedules.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ProtocolConfig
from repro.core import quantize
from repro.core.protocol import GanModelSpec, device_update, server_update
from repro.core.averaging import weighted_average_psum


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map on new jax; jax.experimental.shard_map on 0.4.x
    (where the replication-checker kwarg is `check_rep`, not `check_vma`)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def shard_map_round(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                    device_axes=("data",)):
    """Build a jitted round function over `mesh` with explicit collectives.

    Expects state["disc_opt"]/data/weights stacked over the device axes
    (leading K == prod of device-axis sizes).
    """
    axis = device_axes

    def round_body(state, data_local, weight_local, round_key):
        # inside shard_map: leading stacked axis has local size 1
        my_index = jax.lax.axis_index(axis)
        data_k = jax.tree.map(lambda x: x[0], data_local)
        disc_opt_k = jax.tree.map(lambda x: x[0], state["disc_opt"])
        w_k = weight_local[0]

        disc_k, disc_opt_k, disc_obj = device_update(
            spec, pcfg, state["gen"], state["disc"], disc_opt_k, data_k,
            round_key, my_index)

        # Step 3 — quantized uplink, keyed exactly as the vmap path's
        # `roundtrip_stacked` (device index = this slice's axis index),
        # so both layouts quantize bitwise-identically.
        if pcfg.quantize_bits < 32:
            disc_k = quantize.roundtrip(
                quantize.device_uplink_key(round_key, my_index), disc_k,
                pcfg.quantize_bits)

        # Algorithm 2 as an explicit weighted psum over the device axes.
        disc_avg = weighted_average_psum(disc_k, w_k, axis_names=axis)

        disc_for_gen = disc_avg if pcfg.schedule == "serial" else state["disc"]
        gen, gen_opt, gen_obj = server_update(
            spec, pcfg, state["gen"], state["gen_opt"], disc_for_gen,
            round_key)

        w = w_k.astype(jnp.float32)
        wsum = jnp.maximum(jax.lax.psum(w, axis), 1e-12)
        metrics = {
            "disc_objective": jax.lax.psum(disc_obj * w, axis) / wsum,
            "gen_objective": gen_obj,
            "participation": jax.lax.pmean((w > 0).astype(jnp.float32), axis),
        }
        new_state = {
            "gen": gen, "disc": disc_avg, "gen_opt": gen_opt,
            "disc_opt": jax.tree.map(lambda x: x[None], disc_opt_k),
        }
        return new_state, metrics

    stacked = P(device_axes)
    rep = P()
    state_specs = {"gen": rep, "disc": rep, "gen_opt": rep,
                   "disc_opt": stacked}

    def make_specs(tree, spec_leaf):
        return jax.tree.map(lambda _: spec_leaf, tree,
                            is_leaf=lambda x: x is None)

    def run(state, data_stacked, weights, round_key):
        in_specs = (
            {k: make_specs(state[k], v) for k, v in state_specs.items()},
            make_specs(data_stacked, stacked),
            stacked,
            rep,
        )
        out_specs = (
            {"gen": make_specs(state["gen"], rep),
             "disc": make_specs(state["disc"], rep),
             "gen_opt": make_specs(state["gen_opt"], rep),
             "disc_opt": make_specs(state["disc_opt"], stacked)},
            {"disc_objective": rep, "gen_objective": rep, "participation": rep},
        )
        fn = _shard_map(round_body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
        return jax.jit(fn)(state, data_stacked, weights, round_key)

    return run

"""Mesh execution layout for protocol rounds: shard_map + explicit
collectives, single-round and FUSED multi-round, for EVERY mesh-capable
algorithm (proposed protocol AND the FedGAN baseline), on a 1-D
`(device,)` or 2-D `(device, model)` mesh.

The round engine has two first-class execution layouts (see
core/engine.py for the driver/layout matrix):

  layout="stacked" — the paper's K devices are a stacked leading axis;
      vmap/GSPMD insert the averaging all-reduce (`protocol.gan_round`,
      `protocol.rounds_scan`).
  layout="mesh"    — THIS module: every mesh slice IS a device under
      `jax.shard_map`. Local updates touch no collective (Algorithm 1,
      and FedGAN's joint D+G local iterations, are embarrassingly
      parallel), Algorithm-2-style averaging is an explicit weighted
      reduction over the device axes, and any replicated server math is
      shared-seed computation (identical per-slice results, no gradient
      collective).

TENSOR PARALLELISM (`tp_axis`/`tp`): each paper-worker slice may itself
be a TP group over the mesh's `model` axis. The TP-shardable leaves
(`sharding.rules.tp_leaf_dim` name rules) enter shard_map split over
`tp_axis`, the per-slice model math runs Megatron column/row-parallel
matmuls with nested psum/all_gather collectives on the model axis
(nn/tp.py pairs, baked into the TP-aware `GanModelSpec`), while
EVERYTHING the paper defines over workers — scheduling masks, channel
timing, the quantized uplink keying, and the Algorithm-2 reduction —
stays on the DEVICE axes only. Each TP rank therefore averages just its
parameter shard: the Algorithm-2 all-gather payload shrinks by the TP
factor. The uplink quantizer reconstructs the worker-global stream and
scale per shard (`quantize.roundtrip_tp`), so tp>1 quantizes
bitwise-identically to tp=1 given the same values; tp=1 (the default)
takes the exact pre-TP code paths.

The engine is ALGORITHM-PARAMETRIC: `_mesh_single_round` and
`_mesh_rounds_scan` own all the layout plumbing — state (un)stacking,
Step 1 scheduling + channel timing via `protocol.schedule_and_time`
(per-round keys shared verbatim with the stacked engine, so masks agree
bitwise across layouts), the wall-clock composition, the donated
`lax.scan` dispatch, and the shard_map spec construction — while a
per-slice ROUND BODY supplies the algorithm's Steps 2-5:

  `_proposed_slice_round` — Algorithm 1 local disc steps, the quantized
      one-net uplink, Algorithm 2 over the device axes, the replicated
      Algorithm 3 server update.
  `_fedgan_slice_round`   — FedGAN's n_d local (disc, gen) iteration
      pairs, the single TWO-NET quantized uplink payload (keyed exactly
      like `fedgan_round`'s `roundtrip_stacked`, so both layouts
      quantize bitwise-identically), and Algorithm-2-style averaging of
      BOTH networks in one reduction.

Four entry points, two per algorithm:

  `shard_map_round` / `fedgan_shard_map_round` — ONE round per dispatch
      (weights supplied by the host). The per-round oracles of the mesh
      layout and the baselines `benchmarks/driver_bench.py --layout
      mesh` measures fused speedups against.
  `shard_rounds_scan` / `fedgan_shard_rounds_scan` — the fused engines:
      R complete rounds run INSIDE shard_map as one `lax.scan` — one
      XLA dispatch per chunk, donated state, the same carry/out
      structure as `protocol.rounds_scan`, so `engine.Trainer` drives
      either through the unchanged fused driver.

Every builder MEMOIZES on its full (mesh, config) signature at module
level, so repeated `Trainer` constructions (or `build_train_step`
calls) in one process reuse the jitted shard_map closures — and their
compiles — instead of rebuilding per call. Inside a builder the jitted
closure is additionally keyed by the state/data tree signature, so one
builder serves differently-shaped models without stale specs.

Algorithm 2 on the mesh defaults to
`averaging.weighted_average_psum(impl="pallas")`: the local tree (both
nets, for FedGAN; each rank's shards, under TP) is flattened into ONE
payload, all-gathered once over the DEVICE axes, and reduced by the
Pallas `wavg` kernel on the MXU (interpret mode on CPU) — one
collective + one kernel per round instead of a per-leaf psum tree.

Equivalence contract (tests/test_driver_equivalence.py mesh matrices,
tests/test_multidevice.py, tests/test_tp_equivalence.py): on a forced
multi-device host mesh both layouts of BOTH algorithms — at tp=1 AND
tp=2 — reproduce the host oracle's masks BITWISE (the per-round keys
come from `protocol.schedule_and_time`, shared verbatim) and its
params/metrics to float32 round-off.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ProtocolConfig
from repro.core import faults as faults_lib
from repro.core import fedgan as fedgan_mod
from repro.core import jax_channel, quantize
from repro.core.protocol import (GanModelSpec, count_params, device_update,
                                 schedule_and_time, server_update,
                                 uplink_payload_bits)
from repro.core.averaging import weighted_average_psum
from repro.sharding import rules

# Per-algorithm mesh conventions: which state entries carry a leading
# per-device axis, the metric names the slice round body returns (they
# must match the host oracle's round function exactly, since the
# equivalence tests compare metric dicts key-for-key), and the uplink
# payload tree (whose structure keys the TP shard dims for the
# quantizer — `rules.tp_tree_dims` on the GLOBAL state).
PROPOSED_STACKED_KEYS = ("disc_opt",)
PROPOSED_METRICS = ("disc_objective", "gen_objective", "participation")
PROPOSED_PAYLOAD = lambda state: state["disc"]
FEDGAN_STACKED_KEYS = ("gen_opt", "disc_opt")
FEDGAN_METRICS = ("participation",)
FEDGAN_PAYLOAD = lambda state: {"gen": state["gen"],
                                "disc": state["disc"]}


@dataclasses.dataclass(frozen=True)
class TpCtx:
    """In-slice tensor-parallel context handed to the slice round
    bodies: the model-axis name, its (static) size, and the uplink
    payload's per-leaf shard dims (tree_flatten-aligned tuple, computed
    on the GLOBAL payload by `rules.tp_tree_dims`)."""
    axis: str
    size: int
    payload_dims: Tuple


def _shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map — shared with the serving engine."""
    from repro.launch.mesh import shard_map_compat
    return shard_map_compat(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs)


def _unstack_state(state, stacked_keys):
    """Drop the local size-1 leading axis of the per-device entries."""
    return {k: (jax.tree.map(lambda x: x[0], v) if k in stacked_keys else v)
            for k, v in state.items()}


def _restack_state(state, stacked_keys):
    """Re-add the local leading axis so out specs see the stacked shape."""
    return {k: (jax.tree.map(lambda x: x[None], v) if k in stacked_keys
                else v)
            for k, v in state.items()}


def _tree_sig(tree):
    """Hashable (treedef, shapes/dtypes) signature of a pytree — the
    per-builder closure-cache key, so one memoized builder serves
    differently-shaped states without reusing stale specs."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, tuple((tuple(x.shape), str(getattr(x, "dtype", "?")))
                          for x in leaves)


# Per-builder jitted-closure cache bound: builders live in the
# module-level _BUILDER_CACHE, so their inner per-signature caches
# would otherwise outlive every Trainer and accumulate one compiled
# executable per distinct model shape for the process lifetime (e.g. a
# width sweep reusing one spec object). Real runs use one or two
# signatures per builder; LRU-evict beyond a small bound.
_SIG_CACHE_MAX = 8


def _sig_cache_get(cache: dict, sig, build: Callable,
                   cap: int = _SIG_CACHE_MAX):
    fn = cache.pop(sig, None)    # pop+reinsert: LRU recency
    if fn is None:
        fn = build()
    cache[sig] = fn
    while len(cache) > cap:
        cache.pop(next(iter(cache)))
    return fn


def _tp_ctx(payload_fn, state, tp_axis, tp) -> Optional[TpCtx]:
    """TpCtx from the GLOBAL state (divisibility decided on global
    dims), or None when the model axis is absent/trivial."""
    if tp_axis is None or tp <= 1:
        return None
    return TpCtx(tp_axis, tp, rules.tp_tree_dims(payload_fn(state), tp))


def _quantize_uplink(tp_ctx: Optional[TpCtx], key, payload, bits: int):
    """The Step-3 uplink quantizer, per TP regime: the plain worker
    stream at tp=1, the worker-global reconstructed stream per shard
    under TP (bitwise-identical results for identical values)."""
    if tp_ctx is None:
        return quantize.roundtrip(key, payload, bits)
    return quantize.roundtrip_tp(key, payload, bits, tp_axis=tp_ctx.axis,
                                 tp=tp_ctx.size,
                                 shard_dims=tp_ctx.payload_dims)


# ---------------------------------------------------------------------------
# Per-slice round bodies (Steps 2-5, one algorithm each)
# ---------------------------------------------------------------------------

def _proposed_slice_round(spec: GanModelSpec, pcfg: ProtocolConfig, axis,
                          faults, robust,
                          avg_impl: str, tp_ctx: Optional[TpCtx], my_index,
                          st, data_k, w_k, weights, weight_sum, round_key):
    """The proposed protocol's Steps 2-5 as seen by ONE mesh slice.

    st: per-slice state {"gen", "disc", "gen_opt", "disc_opt"} (already
    unstacked; under TP every model-parallel leaf is this rank's
    shard — the spec's apply functions own the in-slice collectives).
    An optional replicated "fault" entry carries the free-rider stale
    cache (core/faults.py); `faults` corrupts THIS slice's upload keyed
    by (round_key, my_index) — bitwise what the stacked layout's
    vmapped lane realizes — and `robust` selects the robust reducer in
    the Algorithm-2 reduction.
    Returns (new_st, metrics).
    """
    disc_k, disc_opt_k, disc_obj = device_update(
        spec, pcfg, st["gen"], st["disc"], st["disc_opt"], data_k,
        round_key, my_index)

    if avg_impl == "ring":
        # Ring hot path: the quantized uplink stays ENCODED on the wire
        # — weighted_average_psum(impl="ring") quantizes with the SAME
        # device_uplink_key stream as the flat path's roundtrip and
        # streams the int16 payload around a chunked ppermute ring with
        # dequantize-and-accumulate fused into the Pallas kernel
        # (kernels/ring_wavg). Corrupting faults / robust reducers
        # operate on dequantized trees, so they are flat-path-only
        # (rejected at build time by `check_ring_support`).
        disc_avg = weighted_average_psum(
            disc_k, w_k, axis_names=axis, impl="ring",
            quantize_key=quantize.device_uplink_key(round_key, my_index),
            quantize_bits=pcfg.quantize_bits, fallback=st["disc"])
    else:
        # Step 3 — quantized uplink, keyed exactly as the stacked
        # layout's `roundtrip_stacked` (device index = this slice's
        # DEVICE-axes index, shared by all its TP ranks), so every
        # layout and TP width quantizes bitwise-identically.
        if pcfg.quantize_bits < 32:
            disc_k = _quantize_uplink(
                tp_ctx, quantize.device_uplink_key(round_key, my_index),
                disc_k, pcfg.quantize_bits)

        prog = faults_lib.fault_program(faults)
        if prog is not None and prog.corrupts:
            stale = st["fault"]["stale"] if "fault" in st else None
            disc_k = faults_lib.corrupt_upload(prog, round_key, my_index,
                                               disc_k, stale=stale)

        # Algorithm 2 over the DEVICE axes only — Pallas wavg kernel on
        # the flat all-gathered payload by default (one collective + one
        # kernel), per-leaf psum with impl="jnp"; `robust` routes the
        # SAME flat-gather path through a robust reducer. Under TP each
        # rank reduces just its shard: the gathered payload is 1/tp the
        # model. On a no-survivor round the fallback keeps the previous
        # global discriminator.
        disc_avg = weighted_average_psum(disc_k, w_k, axis_names=axis,
                                         impl=avg_impl, robust=robust,
                                         fallback=st["disc"])

    disc_for_gen = disc_avg if pcfg.schedule == "serial" else st["disc"]
    gen, gen_opt, gen_obj = server_update(spec, pcfg, st["gen"],
                                          st["gen_opt"], disc_for_gen,
                                          round_key)

    w = w_k.astype(jnp.float32)
    wsum = jnp.maximum(weight_sum, 1e-12)
    metrics = {
        "disc_objective": jax.lax.psum(disc_obj * w, axis) / wsum,
        "gen_objective": gen_obj,
        "participation": (weights > 0).astype(jnp.float32).mean(),
    }
    new_st = {"gen": gen, "disc": disc_avg, "gen_opt": gen_opt,
              "disc_opt": disc_opt_k}
    if "fault" in st:
        new_st["fault"] = {"stale": st["disc"]}
    return new_st, metrics


def _fedgan_slice_round(spec: GanModelSpec, pcfg: ProtocolConfig, axis,
                        faults, robust,
                        avg_impl: str, tp_ctx: Optional[TpCtx], my_index,
                        st, data_k, w_k, weights, weight_sum, round_key):
    """One FedGAN round as seen by ONE mesh slice: n_d local (disc, gen)
    iteration pairs on the slice's shard, then the server's model-only
    averaging of BOTH networks.

    The uplink is the single two-net payload of `fedgan.fedgan_round`:
    {"gen": ..., "disc": ...} quantized as ONE tree per device (one
    stochastic-rounding draw over the concatenated payload), keyed by
    `device_uplink_key(round_key, my_index)` — the same tree structure
    and key `roundtrip_stacked` uses on the stacked layout, so both
    layouts quantize bitwise-identically (under TP each rank draws its
    shard's slice of that same stream). Averaging reduces the same
    combined tree in one `weighted_average_psum` call over the device
    axes: with impl="pallas" that is ONE all-gather + ONE wavg kernel
    for both networks — per TP rank, 1/tp of the two-net payload.
    """
    gen_k, disc_k, gen_opt_k, disc_opt_k = fedgan_mod.fedgan_device_update(
        spec, pcfg, st["gen"], st["disc"], st["gen_opt"], st["disc_opt"],
        data_k, round_key, my_index)

    payload = {"gen": gen_k, "disc": disc_k}
    prev = {"gen": st["gen"], "disc": st["disc"]}
    if avg_impl == "ring":
        # Same ring hot path as the proposed protocol: one encoded
        # two-net payload streamed around the ring, dequantized in the
        # accumulate kernel (see _proposed_slice_round).
        avg = weighted_average_psum(
            payload, w_k, axis_names=axis, impl="ring",
            quantize_key=quantize.device_uplink_key(round_key, my_index),
            quantize_bits=pcfg.quantize_bits, fallback=prev)
    else:
        if pcfg.quantize_bits < 32:
            payload = _quantize_uplink(
                tp_ctx, quantize.device_uplink_key(round_key, my_index),
                payload, pcfg.quantize_bits)

        prog = faults_lib.fault_program(faults)
        if prog is not None and prog.corrupts:
            stale = st["fault"]["stale"] if "fault" in st else None
            payload = faults_lib.corrupt_upload(prog, round_key, my_index,
                                                payload, stale=stale)

        avg = weighted_average_psum(payload, w_k, axis_names=axis,
                                    impl=avg_impl, robust=robust,
                                    fallback=prev)
    new_st = {"gen": avg["gen"], "disc": avg["disc"],
              "gen_opt": gen_opt_k, "disc_opt": disc_opt_k}
    if "fault" in st:
        new_st["fault"] = {"stale": {"gen": st["gen"],
                                     "disc": st["disc"]}}
    metrics = {"participation": (weights > 0).astype(jnp.float32).mean()}
    return new_st, metrics


# ---------------------------------------------------------------------------
# One round per dispatch (host-scheduled weights — the mesh oracles)
# ---------------------------------------------------------------------------

def _mesh_single_round(slice_round_fn: Callable, stacked_keys, metric_names,
                       payload_fn: Callable, mesh, device_axes,
                       avg_impl: str, tp_axis=None, tp: int = 1):
    """Build a jitted single-round function over `mesh` with explicit
    collectives. Expects the `stacked_keys` state entries /data/weights
    stacked over the device axes (leading K == prod of device-axis
    sizes); TP-shardable leaves enter split over `tp_axis` when set.

    The jitted shard_map closure is cached per state/data signature, so
    repeated per-round dispatches pay dispatch latency only — this is
    the baseline the fused scans are benchmarked against. It runs the
    SAME per-slice round math (including the averaging impl, pallas by
    default), so the driver bench isolates pure dispatch overhead.
    """
    axis = device_axes
    stacked, rep = P(device_axes), P()
    cache = {}

    def build(state, data_stacked):
        tp_ctx = _tp_ctx(payload_fn, state, tp_axis, tp)

        def round_body(state, data_local, weight_local, round_key):
            # inside shard_map: leading stacked axis has local size 1
            my_index = jax.lax.axis_index(axis)
            data_k = jax.tree.map(lambda x: x[0], data_local)
            st = _unstack_state(state, stacked_keys)
            w_k = weight_local[0]
            weights = jax.lax.all_gather(w_k, axis)
            wsum = jax.lax.psum(w_k.astype(jnp.float32), axis)
            new_st, metrics = slice_round_fn(
                avg_impl, tp_ctx, my_index, st, data_k, w_k, weights,
                wsum, round_key)
            return _restack_state(new_st, stacked_keys), metrics

        in_specs = (
            rules.shard_round_state_specs(state, device_axes,
                                          stacked_keys,
                                          tp_axis=tp_axis, tp=tp),
            rules.tree_specs(data_stacked, stacked),
            stacked,
            rep,
        )
        out_specs = (
            rules.shard_round_state_specs(state, device_axes,
                                          stacked_keys,
                                          tp_axis=tp_axis, tp=tp),
            {name: rep for name in metric_names},
        )
        return jax.jit(_shard_map(round_body, mesh=mesh,
                                  in_specs=in_specs,
                                  out_specs=out_specs))

    def run(state, data_stacked, weights, round_key):
        sig = (_tree_sig(state), _tree_sig(data_stacked))
        fn = _sig_cache_get(cache, sig,
                            lambda: build(state, data_stacked))
        return fn(state, data_stacked, weights, round_key)

    return run


# ---------------------------------------------------------------------------
# Builder memoization — reuse jitted shard_map closures per (mesh, config)
# ---------------------------------------------------------------------------

_BUILDER_CACHE: dict = {}
# LRU bound: spec objects hash by the identity of their callables, so
# callers that rebuild specs per call (sweeps, fresh make_backbone_spec
# per chunk length) insert entries they can never hit again — the
# bound keeps those from pinning compiled executables for the process
# lifetime, while callers that DO reuse spec objects (module-level
# specs, the Trainer tests, repeated Trainer constructions) stay hot.
_BUILDER_CACHE_MAX = 64


def _memo_builder(key_parts, build: Callable):
    """Memoize a builder on its full config signature when every part is
    hashable (specs/pcfg/mesh/scheduler are frozen dataclasses, channel
    keys by its config tuple); unhashable parts fall back to building
    fresh. Correct because every closure input is part of the key and
    the built `run` re-derives its jitted fn per state signature."""
    try:
        key = tuple(key_parts)
        hash(key)
    except TypeError:
        return build()
    return _sig_cache_get(_BUILDER_CACHE, key, build,
                          cap=_BUILDER_CACHE_MAX)


def _channel_key(channel):
    return tuple(dataclasses.astuple(channel.cfg))


def check_faults_tp(faults, robust, tp_axis, tp: int):
    """Fault injection / robust reduction compose with the mesh layout
    at tp=1 only: under TP the per-slice payload is a model-axis shard,
    so byzantine noise keying, the stale cache, and shard-local norms/
    distances would all diverge from the worker-global semantics.

    THE one definition of this contract — called from the mesh round
    builders below, `engine.Trainer`, and `launch.steps`."""
    if tp_axis is not None and tp > 1 and (faults is not None
                                           or robust is not None):
        raise NotImplementedError(
            "faults/robust reducers are not supported under tensor "
            "parallelism (tp > 1); run tp=1")


# Backwards-compatible alias (pre-PR-9 private name).
_check_faults_tp = check_faults_tp


def check_ring_support(avg_impl: str, device_axes, tp_axis, tp: int,
                       faults, robust):
    """Build-time contract for `avg_impl="ring"`: a single device axis
    (the ring order is the axis order), tp == 1 (the encoded payload is
    worker-global), no robust reducers and no upload-corrupting fault
    programs (both operate on dequantized per-worker trees, which the
    ring never materializes — they stay on the flat gather path).
    Dropout/straggler fault programs compose fine: they only zero
    weights."""
    if avg_impl != "ring":
        return
    axes = (device_axes if isinstance(device_axes, (tuple, list))
            else (device_axes,))
    if len(axes) != 1:
        raise NotImplementedError(
            f"avg_impl='ring' reduces over a single device axis; "
            f"got {tuple(axes)!r}")
    if tp_axis is not None and tp > 1:
        raise NotImplementedError(
            "avg_impl='ring' is not supported under tensor parallelism "
            "(tp > 1); the encoded ring payload is worker-global")
    if robust is not None:
        raise NotImplementedError(
            "avg_impl='ring' does not compose with robust reducers; "
            "use the flat path (avg_impl='pallas')")
    prog = faults_lib.fault_program(faults)
    if prog is not None and prog.corrupts:
        raise NotImplementedError(
            "avg_impl='ring' does not compose with upload-corrupting "
            "fault programs (free riders / byzantine); use the flat "
            "path (avg_impl='pallas')")


def shard_map_round(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                    device_axes=("data",), avg_impl: str = "pallas",
                    tp_axis=None, tp: int = 1, faults=None, robust=None):
    """Single proposed-protocol round per dispatch (the mesh oracle).
    With `faults`, the host drives scheduling/dropout and this dispatch
    realizes the matching upload corruption; `robust` selects the
    Algorithm-2 robust reducer."""
    check_faults_tp(faults, robust, tp_axis, tp)
    check_ring_support(avg_impl, device_axes, tp_axis, tp, faults,
                       robust)
    return _memo_builder(
        ("proposed_round", spec, pcfg, mesh, tuple(device_axes), avg_impl,
         tp_axis, tp, faults, robust),
        lambda: _mesh_single_round(
            partial(_proposed_slice_round, spec, pcfg, device_axes,
                    faults, robust),
            PROPOSED_STACKED_KEYS, PROPOSED_METRICS, PROPOSED_PAYLOAD,
            mesh, device_axes, avg_impl, tp_axis, tp))


def fedgan_shard_map_round(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                           device_axes=("data",),
                           avg_impl: str = "pallas",
                           tp_axis=None, tp: int = 1, faults=None,
                           robust=None):
    """Single FedGAN round per dispatch (the mesh FedGAN oracle).
    Expects gen_opt AND disc_opt stacked (every device trains both
    nets)."""
    check_faults_tp(faults, robust, tp_axis, tp)
    check_ring_support(avg_impl, device_axes, tp_axis, tp, faults,
                       robust)
    return _memo_builder(
        ("fedgan_round", spec, pcfg, mesh, tuple(device_axes), avg_impl,
         tp_axis, tp, faults, robust),
        lambda: _mesh_single_round(
            partial(_fedgan_slice_round, spec, pcfg, device_axes,
                    faults, robust),
            FEDGAN_STACKED_KEYS, FEDGAN_METRICS, FEDGAN_PAYLOAD,
            mesh, device_axes, avg_impl, tp_axis, tp))


# ---------------------------------------------------------------------------
# Fused multi-round scan INSIDE shard_map — R rounds per dispatch
# ---------------------------------------------------------------------------

def _mesh_rounds_scan(slice_round_fn: Callable, stacked_keys, metric_names,
                      payload_fn: Callable, pcfg: ProtocolConfig, mesh,
                      n_rounds: int, *, channel, scheduler, device_axes,
                      disc_step_flops: float, gen_step_flops: float,
                      uplink_bits: Optional[int], avg_impl: str,
                      fedgan: bool, eval_fn: Optional[Callable],
                      eval_every: int, tp_axis=None, tp: int = 1,
                      faults=None):
    """The unified fused round engine on the MESH layout, parametrized
    by the algorithm's per-slice round body.

    Builds `run(state, sched_carry, data_stacked, key, start_round) ->
    (state, sched_carry, out)` — the exact chunk signature of the
    stacked layout's `engine.Trainer._chunk_fn`, with state and
    scheduler carry donated. `out` stacks per-round {"metrics",
    "wallclock_s", "mask", "weights"[, "fid", "fid_eval"]} exactly like
    `protocol.rounds_scan`.

    Everything runs INSIDE shard_map: scheduling and channel timing are
    replicated per-slice computation (deterministic given the round key,
    so every slice agrees without a collective), local updates touch no
    device-axes collective (under TP they carry the in-slice Megatron
    psums on the model axis), the quantized uplink uses the slice's
    DEVICE-axes index as its device key, and the averaging is
    `weighted_average_psum` over the device axes — by default
    `impl="pallas"`: one all-gather of the flat payload (per TP rank,
    1/tp of the model) + one Pallas `wavg` kernel per round
    (interpret-mode on CPU hosts).

    The channel accounting always sees the WORKER-global parameter
    counts and payload bits (computed host-side from the global state),
    so simulated timing/wallclock is identical at every tp — TP is an
    implementation detail inside a worker, invisible to the paper's
    channel model.

    channel:   core.jax_channel.JaxChannel over K = prod(device axes)
    scheduler: core.jax_scheduling.JaxScheduler
    fedgan:    selects the FedGAN timing/wallclock composition and the
        two-net default uplink payload size
    eval_fn:   optional JITTABLE (gen_params, t, key) -> scalar run
        in-scan via lax.cond on rounds where (t+1) % eval_every == 0
        (replicated — gen is replicated, so every slice evaluates the
        same FID). Not supported under tp > 1 (the in-slice gen is a
        shard).
    """
    axis = device_axes
    if (tp_axis is not None and tp > 1 and eval_fn is not None
            and eval_every > 0):
        raise NotImplementedError(
            "in-scan FID under tensor parallelism is not supported: the "
            "per-slice generator is a model-axis shard; run eval_every=0 "
            "or tp=1")
    stacked, rep = P(device_axes), P()
    cache = {}

    def build(state, sched_carry, data_stacked):
        tp_ctx = _tp_ctx(payload_fn, state, tp_axis, tp)
        # Worker-global counts, from the GLOBAL (pre-split) state —
        # inside shard_map the leaves are 1/tp shards under TP.
        disc_nparams = count_params(state["disc"])
        gen_nparams = count_params(state["gen"])
        bits = uplink_bits
        if bits is None:
            bits = uplink_payload_bits(state, pcfg, fedgan=fedgan)

        def body(state, sched_carry, data_local, key, start_round):
            my_index = jax.lax.axis_index(axis)
            data_k = jax.tree.map(lambda x: x[0], data_local)
            st = _unstack_state(state, stacked_keys)

            def round_body(carry, t):
                st, sc = carry
                round_key = jax.random.fold_in(key, t)

                # Step 1 + channel accounting: same helper (same
                # salts, same draw order) as the stacked layout —
                # masks are bitwise identical across layouts and vs
                # the host oracle.
                mask, sc, timing, weights = schedule_and_time(
                    pcfg, channel, scheduler, sc, round_key,
                    disc_nparams=disc_nparams,
                    gen_nparams=gen_nparams,
                    disc_step_flops=disc_step_flops,
                    gen_step_flops=gen_step_flops, fedgan=fedgan,
                    uplink_bits=bits, faults=faults)
                w_k = weights[my_index]

                new_st, metrics = slice_round_fn(
                    avg_impl, tp_ctx, my_index, st, data_k, w_k,
                    weights, weights.sum(), round_key)

                wall = jax_channel.round_wallclock(
                    timing, mask, schedule=pcfg.schedule,
                    fedgan=fedgan)
                out = {"metrics": metrics, "wallclock_s": wall,
                       "mask": mask, "weights": weights}
                if eval_fn is not None and eval_every > 0:
                    do_eval = (t + 1) % eval_every == 0
                    out["fid"] = jax.lax.cond(
                        do_eval,
                        lambda g: jnp.float32(eval_fn(g, t, key)),
                        lambda g: jnp.float32(jnp.nan),
                        new_st["gen"])
                    out["fid_eval"] = do_eval
                return (new_st, sc), out

            rounds = jnp.asarray(start_round) + jnp.arange(n_rounds)
            (st, sched_carry), out = jax.lax.scan(
                round_body, (st, sched_carry), rounds)
            return _restack_state(st, stacked_keys), sched_carry, out

        state_specs = rules.shard_round_state_specs(
            state, device_axes, stacked_keys, tp_axis=tp_axis, tp=tp)
        out_round = {"metrics": {name: rep for name in metric_names},
                     "wallclock_s": rep, "mask": rep, "weights": rep}
        if eval_fn is not None and eval_every > 0:
            out_round["fid"] = rep
            out_round["fid_eval"] = rep
        in_specs = (state_specs,
                    rules.tree_specs(sched_carry, rep),
                    rules.tree_specs(data_stacked, stacked),
                    rep, rep)
        out_specs = (state_specs,
                     rules.tree_specs(sched_carry, rep),
                     out_round)
        return jax.jit(
            _shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs),
            donate_argnums=(0, 1))

    def run(state, sched_carry, data_stacked, key, start_round):
        sig = (_tree_sig(state), _tree_sig(sched_carry),
               _tree_sig(data_stacked))
        fn = _sig_cache_get(
            cache, sig, lambda: build(state, sched_carry, data_stacked))
        return fn(state, sched_carry, data_stacked, key, start_round)

    return run


def _scan_memo_key(kind, spec, pcfg, mesh, n_rounds, channel, scheduler,
                   device_axes, disc_step_flops, gen_step_flops,
                   uplink_bits, avg_impl, tp_axis, tp, faults=None,
                   robust=None):
    return (kind, spec, pcfg, mesh, n_rounds, _channel_key(channel),
            scheduler, tuple(device_axes), disc_step_flops,
            gen_step_flops, uplink_bits, avg_impl, tp_axis, tp, faults,
            robust)


def shard_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                      n_rounds: int, *, channel, scheduler,
                      device_axes=("data",), disc_step_flops: float = 1e9,
                      gen_step_flops: float = 1e9,
                      uplink_bits: Optional[int] = None,
                      avg_impl: str = "pallas",
                      eval_fn: Optional[Callable] = None,
                      eval_every: int = 0, tp_axis=None, tp: int = 1,
                      faults=None, robust=None):
    """R fused rounds of the PROPOSED protocol on the mesh layout
    (see `_mesh_rounds_scan`), keyed bitwise-identically to
    `protocol.gan_rounds_scan` — including the fault realization
    (dropout masks, corruption draws) under a FaultConfig."""
    check_faults_tp(faults, robust, tp_axis, tp)
    check_ring_support(avg_impl, device_axes, tp_axis, tp, faults,
                       robust)
    build = lambda: _mesh_rounds_scan(
        partial(_proposed_slice_round, spec, pcfg, device_axes,
                faults, robust),
        PROPOSED_STACKED_KEYS, PROPOSED_METRICS, PROPOSED_PAYLOAD, pcfg,
        mesh, n_rounds, channel=channel, scheduler=scheduler,
        device_axes=device_axes, disc_step_flops=disc_step_flops,
        gen_step_flops=gen_step_flops, uplink_bits=uplink_bits,
        avg_impl=avg_impl, fedgan=False, eval_fn=eval_fn,
        eval_every=eval_every, tp_axis=tp_axis, tp=tp, faults=faults)
    if eval_fn is not None:
        return build()   # per-run closures; never memoized
    return _memo_builder(
        _scan_memo_key("proposed_scan", spec, pcfg, mesh, n_rounds,
                       channel, scheduler, device_axes, disc_step_flops,
                       gen_step_flops, uplink_bits, avg_impl, tp_axis,
                       tp, faults, robust),
        build)


def fedgan_shard_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                             n_rounds: int, *, channel, scheduler,
                             device_axes=("data",),
                             disc_step_flops: float = 1e9,
                             gen_step_flops: float = 1e9,
                             uplink_bits: Optional[int] = None,
                             avg_impl: str = "pallas",
                             eval_fn: Optional[Callable] = None,
                             eval_every: int = 0, tp_axis=None,
                             tp: int = 1, faults=None, robust=None):
    """R fused FEDGAN rounds on the mesh layout: per-device joint D+G
    local iterations, the single two-net quantized uplink payload,
    Algorithm-2-style averaging of BOTH networks, and the FedGAN
    wall-clock composition — one donated shard_map `lax.scan` dispatch,
    keyed bitwise-identically to `fedgan.fedgan_rounds_scan` so the
    host oracle pins it."""
    check_faults_tp(faults, robust, tp_axis, tp)
    check_ring_support(avg_impl, device_axes, tp_axis, tp, faults,
                       robust)
    build = lambda: _mesh_rounds_scan(
        partial(_fedgan_slice_round, spec, pcfg, device_axes,
                faults, robust),
        FEDGAN_STACKED_KEYS, FEDGAN_METRICS, FEDGAN_PAYLOAD, pcfg, mesh,
        n_rounds, channel=channel, scheduler=scheduler,
        device_axes=device_axes, disc_step_flops=disc_step_flops,
        gen_step_flops=gen_step_flops, uplink_bits=uplink_bits,
        avg_impl=avg_impl, fedgan=True, eval_fn=eval_fn,
        eval_every=eval_every, tp_axis=tp_axis, tp=tp, faults=faults)
    if eval_fn is not None:
        return build()
    return _memo_builder(
        _scan_memo_key("fedgan_scan", spec, pcfg, mesh, n_rounds,
                       channel, scheduler, device_axes, disc_step_flops,
                       gen_step_flops, uplink_bits, avg_impl, tp_axis,
                       tp, faults, robust),
        build)

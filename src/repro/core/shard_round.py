"""Mesh execution layout for protocol rounds: shard_map + explicit
collectives, single-round and FUSED multi-round.

The round engine has two first-class execution layouts (see
core/engine.py for the driver/layout matrix):

  layout="stacked" — the paper's K devices are a stacked leading axis;
      vmap/GSPMD insert the averaging all-reduce (`protocol.gan_round`,
      `protocol.rounds_scan`).
  layout="mesh"    — THIS module: every mesh slice IS a device under
      `jax.shard_map`. Local discriminator steps touch no collective
      (Algorithm 1 is embarrassingly parallel), Algorithm 2 is an
      explicit weighted reduction over the device axes, and the server
      update is replicated shared-seed computation (the paper's single
      server maps to identical per-slice generator math — no gradient
      collective is needed because the shared noise makes every slice
      compute the same update).

Two entry points:

  `shard_map_round`  — ONE round per dispatch (weights supplied by the
      host). The per-round oracle of the mesh layout and the baseline
      the §Perf hillclimb measures fused speedups against.
  `shard_rounds_scan` — the fused engine on the mesh: R complete rounds
      (Step 1 scheduling, channel timing, the quantized uplink keyed
      identically to the stacked layout, Algorithm 2 via the Pallas
      `wavg` kernel by default, and the Fig. 1/2 wall-clock composition)
      run INSIDE shard_map as one `lax.scan` — one XLA dispatch per
      chunk, donated state, same carry/out structure as
      `protocol.rounds_scan`, so `engine.Trainer(layout="mesh")` drives
      it through the unchanged fused driver.

Equivalence contract (tests/test_driver_equivalence.py mesh matrix,
tests/test_multidevice.py): on a forced multi-device host mesh both
layouts reproduce the host oracle's masks BITWISE (the per-round keys
come from `protocol.schedule_and_time`, shared verbatim) and its
params/metrics to float32 round-off.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ProtocolConfig
from repro.core import jax_channel, quantize
from repro.core.protocol import (GanModelSpec, count_params, device_update,
                                 schedule_and_time, server_update,
                                 uplink_payload_bits)
from repro.core.averaging import weighted_average_psum
from repro.sharding import rules


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map on new jax; jax.experimental.shard_map on 0.4.x
    (where the replication-checker kwarg is `check_rep`, not `check_vma`)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _slice_round_body(spec: GanModelSpec, pcfg: ProtocolConfig, axis,
                      avg_impl: str, my_index, gen, disc, gen_opt,
                      disc_opt_k, data_k, w_k, weights, disc_objs_weight_sum,
                      round_key):
    """Steps 2-5 of one round as seen by ONE mesh slice (= one device).

    Shared by the single-round and fused entry points so both layouts of
    the mesh path run literally the same per-round math.
    Returns (gen, disc_avg, gen_opt, disc_opt_k, metrics).
    """
    disc_k, disc_opt_k, disc_obj = device_update(
        spec, pcfg, gen, disc, disc_opt_k, data_k, round_key, my_index)

    # Step 3 — quantized uplink, keyed exactly as the stacked layout's
    # `roundtrip_stacked` (device index = this slice's axis index), so
    # both layouts quantize bitwise-identically.
    if pcfg.quantize_bits < 32:
        disc_k = quantize.roundtrip(
            quantize.device_uplink_key(round_key, my_index), disc_k,
            pcfg.quantize_bits)

    # Algorithm 2 over the device axes — Pallas wavg kernel on the flat
    # all-gathered payload by default (one collective + one kernel),
    # per-leaf psum with impl="jnp".
    disc_avg = weighted_average_psum(disc_k, w_k, axis_names=axis,
                                     impl=avg_impl)

    disc_for_gen = disc_avg if pcfg.schedule == "serial" else disc
    gen, gen_opt, gen_obj = server_update(spec, pcfg, gen, gen_opt,
                                          disc_for_gen, round_key)

    w = w_k.astype(jnp.float32)
    wsum = jnp.maximum(disc_objs_weight_sum, 1e-12)
    metrics = {
        "disc_objective": jax.lax.psum(disc_obj * w, axis) / wsum,
        "gen_objective": gen_obj,
        "participation": (weights > 0).astype(jnp.float32).mean(),
    }
    return gen, disc_avg, gen_opt, disc_opt_k, metrics


# ---------------------------------------------------------------------------
# One round per dispatch (host-scheduled weights — the mesh oracle)
# ---------------------------------------------------------------------------

def shard_map_round(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                    device_axes=("data",)):
    """Build a jitted single-round function over `mesh` with explicit
    collectives. Expects state["disc_opt"]/data/weights stacked over the
    device axes (leading K == prod of device-axis sizes).

    The jitted shard_map closure is built once on first call and cached,
    so repeated per-round dispatches pay dispatch latency only — this is
    the baseline `shard_rounds_scan` is benchmarked against.
    """
    axis = device_axes

    def round_body(state, data_local, weight_local, round_key):
        # inside shard_map: leading stacked axis has local size 1
        my_index = jax.lax.axis_index(axis)
        data_k = jax.tree.map(lambda x: x[0], data_local)
        disc_opt_k = jax.tree.map(lambda x: x[0], state["disc_opt"])
        w_k = weight_local[0]
        weights = jax.lax.all_gather(w_k, axis)
        wsum = jax.lax.psum(w_k.astype(jnp.float32), axis)

        gen, disc_avg, gen_opt, disc_opt_k, metrics = _slice_round_body(
            spec, pcfg, axis, "jnp", my_index, state["gen"], state["disc"],
            state["gen_opt"], disc_opt_k, data_k, w_k, weights, wsum,
            round_key)

        new_state = {
            "gen": gen, "disc": disc_avg, "gen_opt": gen_opt,
            "disc_opt": jax.tree.map(lambda x: x[None], disc_opt_k),
        }
        return new_state, metrics

    stacked, rep = P(device_axes), P()
    cache = {}

    def run(state, data_stacked, weights, round_key):
        if "fn" not in cache:
            in_specs = (
                rules.shard_round_state_specs(state, device_axes),
                rules.tree_specs(data_stacked, stacked),
                stacked,
                rep,
            )
            out_specs = (
                rules.shard_round_state_specs(state, device_axes),
                {"disc_objective": rep, "gen_objective": rep,
                 "participation": rep},
            )
            cache["fn"] = jax.jit(_shard_map(
                round_body, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs))
        return cache["fn"](state, data_stacked, weights, round_key)

    return run


# ---------------------------------------------------------------------------
# Fused multi-round scan INSIDE shard_map — R rounds per dispatch
# ---------------------------------------------------------------------------

def shard_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                      n_rounds: int, *, channel, scheduler,
                      device_axes=("data",), disc_step_flops: float = 1e9,
                      gen_step_flops: float = 1e9,
                      uplink_bits: Optional[int] = None,
                      avg_impl: str = "pallas",
                      eval_fn: Optional[Callable] = None,
                      eval_every: int = 0):
    """The unified fused round engine on the MESH layout.

    Builds `run(state, sched_carry, data_stacked, key, start_round) ->
    (state, sched_carry, out)` — the exact chunk signature of the
    stacked layout's `engine.Trainer._chunk_fn`, with state and
    scheduler carry donated. `out` stacks per-round {"metrics",
    "wallclock_s", "mask", "weights"[, "fid", "fid_eval"]} exactly like
    `protocol.rounds_scan`.

    Everything runs INSIDE shard_map: scheduling and channel timing are
    replicated per-slice computation (deterministic given the round key,
    so every slice agrees without a collective), Algorithm 1 is local to
    each slice, the quantized uplink uses the slice's axis index as its
    device key, and Algorithm 2 is `weighted_average_psum` — by default
    `impl="pallas"`: one all-gather of the flat payload + one Pallas
    `wavg` kernel per round (interpret-mode on CPU hosts).

    channel:   core.jax_channel.JaxChannel over K = prod(device axes)
    scheduler: core.jax_scheduling.JaxScheduler
    eval_fn:   optional JITTABLE (gen_params, t, key) -> scalar run
        in-scan via lax.cond on rounds where (t+1) % eval_every == 0
        (replicated — gen is replicated, so every slice evaluates the
        same FID).
    """
    axis = device_axes

    def body(state, sched_carry, data_local, key, start_round):
        my_index = jax.lax.axis_index(axis)
        data_k = jax.tree.map(lambda x: x[0], data_local)
        st = {"gen": state["gen"], "disc": state["disc"],
              "gen_opt": state["gen_opt"],
              "disc_opt": jax.tree.map(lambda x: x[0], state["disc_opt"])}
        disc_nparams = count_params(st["disc"])
        gen_nparams = count_params(st["gen"])
        bits = uplink_bits
        if bits is None:
            bits = uplink_payload_bits(st, pcfg, fedgan=False)

        def round_body(carry, t):
            st, sc = carry
            round_key = jax.random.fold_in(key, t)

            # Step 1 + channel accounting: same helper (same salts, same
            # draw order) as the stacked layout — masks are bitwise
            # identical across layouts and vs the host oracle.
            mask, sc, timing, weights = schedule_and_time(
                pcfg, channel, scheduler, sc, round_key,
                disc_nparams=disc_nparams, gen_nparams=gen_nparams,
                disc_step_flops=disc_step_flops,
                gen_step_flops=gen_step_flops, fedgan=False,
                uplink_bits=bits)
            w_k = weights[my_index]
            wsum = jnp.maximum(weights.sum(), 1e-12)

            gen, disc_avg, gen_opt, disc_opt_k, metrics = _slice_round_body(
                spec, pcfg, axis, avg_impl, my_index, st["gen"], st["disc"],
                st["gen_opt"], st["disc_opt"], data_k, w_k, weights, wsum,
                round_key)

            wall = jax_channel.round_wallclock(timing, mask,
                                               schedule=pcfg.schedule)
            new_st = {"gen": gen, "disc": disc_avg, "gen_opt": gen_opt,
                      "disc_opt": disc_opt_k}
            out = {"metrics": metrics, "wallclock_s": wall, "mask": mask,
                   "weights": weights}
            if eval_fn is not None and eval_every > 0:
                do_eval = (t + 1) % eval_every == 0
                out["fid"] = jax.lax.cond(
                    do_eval,
                    lambda g: jnp.float32(eval_fn(g, t, key)),
                    lambda g: jnp.float32(jnp.nan), new_st["gen"])
                out["fid_eval"] = do_eval
            return (new_st, sc), out

        rounds = jnp.asarray(start_round) + jnp.arange(n_rounds)
        (st, sched_carry), out = jax.lax.scan(round_body,
                                              (st, sched_carry), rounds)
        new_state = {"gen": st["gen"], "disc": st["disc"],
                     "gen_opt": st["gen_opt"],
                     "disc_opt": jax.tree.map(lambda x: x[None],
                                              st["disc_opt"])}
        return new_state, sched_carry, out

    stacked, rep = P(device_axes), P()
    cache = {}

    def run(state, sched_carry, data_stacked, key, start_round):
        if "fn" not in cache:
            state_specs = rules.shard_round_state_specs(state, device_axes)
            out_round = {"metrics": {"disc_objective": rep,
                                     "gen_objective": rep,
                                     "participation": rep},
                         "wallclock_s": rep, "mask": rep, "weights": rep}
            if eval_fn is not None and eval_every > 0:
                out_round["fid"] = rep
                out_round["fid_eval"] = rep
            in_specs = (state_specs,
                        rules.tree_specs(sched_carry, rep),
                        rules.tree_specs(data_stacked, stacked),
                        rep, rep)
            out_specs = (state_specs,
                         rules.tree_specs(sched_carry, rep),
                         out_round)
            cache["fn"] = jax.jit(
                _shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs),
                donate_argnums=(0, 1))
        return cache["fn"](state, sched_carry, data_stacked, key,
                           start_round)

    return run

"""Mesh execution layout for protocol rounds: shard_map + explicit
collectives, single-round and FUSED multi-round, for EVERY mesh-capable
algorithm (proposed protocol AND the FedGAN baseline).

The round engine has two first-class execution layouts (see
core/engine.py for the driver/layout matrix):

  layout="stacked" — the paper's K devices are a stacked leading axis;
      vmap/GSPMD insert the averaging all-reduce (`protocol.gan_round`,
      `protocol.rounds_scan`).
  layout="mesh"    — THIS module: every mesh slice IS a device under
      `jax.shard_map`. Local updates touch no collective (Algorithm 1,
      and FedGAN's joint D+G local iterations, are embarrassingly
      parallel), Algorithm-2-style averaging is an explicit weighted
      reduction over the device axes, and any replicated server math is
      shared-seed computation (identical per-slice results, no gradient
      collective).

The engine is ALGORITHM-PARAMETRIC: `_mesh_single_round` and
`_mesh_rounds_scan` own all the layout plumbing — state (un)stacking,
Step 1 scheduling + channel timing via `protocol.schedule_and_time`
(per-round keys shared verbatim with the stacked engine, so masks agree
bitwise across layouts), the wall-clock composition, the donated
`lax.scan` dispatch, and the shard_map spec construction — while a
per-slice ROUND BODY supplies the algorithm's Steps 2-5:

  `_proposed_slice_round` — Algorithm 1 local disc steps, the quantized
      one-net uplink, Algorithm 2 over the device axes, the replicated
      Algorithm 3 server update.
  `_fedgan_slice_round`   — FedGAN's n_d local (disc, gen) iteration
      pairs, the single TWO-NET quantized uplink payload (keyed exactly
      like `fedgan_round`'s `roundtrip_stacked`, so both layouts
      quantize bitwise-identically), and Algorithm-2-style averaging of
      BOTH networks in one reduction.

Four entry points, two per algorithm:

  `shard_map_round` / `fedgan_shard_map_round` — ONE round per dispatch
      (weights supplied by the host). The per-round oracles of the mesh
      layout and the baselines `benchmarks/driver_bench.py --layout
      mesh` measures fused speedups against.
  `shard_rounds_scan` / `fedgan_shard_rounds_scan` — the fused engines:
      R complete rounds run INSIDE shard_map as one `lax.scan` — one
      XLA dispatch per chunk, donated state, the same carry/out
      structure as `protocol.rounds_scan`, so `engine.Trainer` drives
      either through the unchanged fused driver.

Algorithm 2 on the mesh defaults to
`averaging.weighted_average_psum(impl="pallas")`: the local tree (both
nets, for FedGAN) is flattened into ONE payload, all-gathered once, and
reduced by the Pallas `wavg` kernel on the MXU (interpret mode on CPU)
— one collective + one kernel per round instead of a per-leaf psum
tree.

Equivalence contract (tests/test_driver_equivalence.py mesh matrices,
tests/test_multidevice.py): on a forced multi-device host mesh both
layouts of BOTH algorithms reproduce the host oracle's masks BITWISE
(the per-round keys come from `protocol.schedule_and_time`, shared
verbatim) and its params/metrics to float32 round-off.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ProtocolConfig
from repro.core import fedgan as fedgan_mod
from repro.core import jax_channel, quantize
from repro.core.protocol import (GanModelSpec, count_params, device_update,
                                 schedule_and_time, server_update,
                                 uplink_payload_bits)
from repro.core.averaging import weighted_average_psum
from repro.sharding import rules

# Per-algorithm mesh conventions: which state entries carry a leading
# per-device axis, and the metric names the slice round body returns
# (they must match the host oracle's round function exactly, since the
# equivalence tests compare metric dicts key-for-key).
PROPOSED_STACKED_KEYS = ("disc_opt",)
PROPOSED_METRICS = ("disc_objective", "gen_objective", "participation")
FEDGAN_STACKED_KEYS = ("gen_opt", "disc_opt")
FEDGAN_METRICS = ("participation",)


def _shard_map(f, *, mesh, in_specs, out_specs):
    """jax.shard_map on new jax; jax.experimental.shard_map on 0.4.x
    (where the replication-checker kwarg is `check_rep`, not `check_vma`)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _unstack_state(state, stacked_keys):
    """Drop the local size-1 leading axis of the per-device entries."""
    return {k: (jax.tree.map(lambda x: x[0], v) if k in stacked_keys else v)
            for k, v in state.items()}


def _restack_state(state, stacked_keys):
    """Re-add the local leading axis so out specs see the stacked shape."""
    return {k: (jax.tree.map(lambda x: x[None], v) if k in stacked_keys
                else v)
            for k, v in state.items()}


# ---------------------------------------------------------------------------
# Per-slice round bodies (Steps 2-5, one algorithm each)
# ---------------------------------------------------------------------------

def _proposed_slice_round(spec: GanModelSpec, pcfg: ProtocolConfig, axis,
                          avg_impl: str, my_index, st, data_k, w_k, weights,
                          weight_sum, round_key):
    """The proposed protocol's Steps 2-5 as seen by ONE mesh slice.

    st: per-slice state {"gen", "disc", "gen_opt", "disc_opt"} (already
    unstacked). Returns (new_st, metrics).
    """
    disc_k, disc_opt_k, disc_obj = device_update(
        spec, pcfg, st["gen"], st["disc"], st["disc_opt"], data_k,
        round_key, my_index)

    # Step 3 — quantized uplink, keyed exactly as the stacked layout's
    # `roundtrip_stacked` (device index = this slice's axis index), so
    # both layouts quantize bitwise-identically.
    if pcfg.quantize_bits < 32:
        disc_k = quantize.roundtrip(
            quantize.device_uplink_key(round_key, my_index), disc_k,
            pcfg.quantize_bits)

    # Algorithm 2 over the device axes — Pallas wavg kernel on the flat
    # all-gathered payload by default (one collective + one kernel),
    # per-leaf psum with impl="jnp".
    disc_avg = weighted_average_psum(disc_k, w_k, axis_names=axis,
                                     impl=avg_impl)

    disc_for_gen = disc_avg if pcfg.schedule == "serial" else st["disc"]
    gen, gen_opt, gen_obj = server_update(spec, pcfg, st["gen"],
                                          st["gen_opt"], disc_for_gen,
                                          round_key)

    w = w_k.astype(jnp.float32)
    wsum = jnp.maximum(weight_sum, 1e-12)
    metrics = {
        "disc_objective": jax.lax.psum(disc_obj * w, axis) / wsum,
        "gen_objective": gen_obj,
        "participation": (weights > 0).astype(jnp.float32).mean(),
    }
    new_st = {"gen": gen, "disc": disc_avg, "gen_opt": gen_opt,
              "disc_opt": disc_opt_k}
    return new_st, metrics


def _fedgan_slice_round(spec: GanModelSpec, pcfg: ProtocolConfig, axis,
                        avg_impl: str, my_index, st, data_k, w_k, weights,
                        weight_sum, round_key):
    """One FedGAN round as seen by ONE mesh slice: n_d local (disc, gen)
    iteration pairs on the slice's shard, then the server's model-only
    averaging of BOTH networks.

    The uplink is the single two-net payload of `fedgan.fedgan_round`:
    {"gen": ..., "disc": ...} quantized as ONE tree per device (one
    stochastic-rounding draw over the concatenated payload), keyed by
    `device_uplink_key(round_key, my_index)` — the same tree structure
    and key `roundtrip_stacked` uses on the stacked layout, so both
    layouts quantize bitwise-identically. Averaging reduces the same
    combined tree in one `weighted_average_psum` call: with
    impl="pallas" that is ONE all-gather + ONE wavg kernel for both
    networks.
    """
    gen_k, disc_k, gen_opt_k, disc_opt_k = fedgan_mod.fedgan_device_update(
        spec, pcfg, st["gen"], st["disc"], st["gen_opt"], st["disc_opt"],
        data_k, round_key, my_index)

    payload = {"gen": gen_k, "disc": disc_k}
    if pcfg.quantize_bits < 32:
        payload = quantize.roundtrip(
            quantize.device_uplink_key(round_key, my_index), payload,
            pcfg.quantize_bits)

    avg = weighted_average_psum(payload, w_k, axis_names=axis,
                                impl=avg_impl)
    new_st = {"gen": avg["gen"], "disc": avg["disc"],
              "gen_opt": gen_opt_k, "disc_opt": disc_opt_k}
    metrics = {"participation": (weights > 0).astype(jnp.float32).mean()}
    return new_st, metrics


# ---------------------------------------------------------------------------
# One round per dispatch (host-scheduled weights — the mesh oracles)
# ---------------------------------------------------------------------------

def _mesh_single_round(slice_round_fn: Callable, stacked_keys, metric_names,
                       mesh, device_axes, avg_impl: str):
    """Build a jitted single-round function over `mesh` with explicit
    collectives. Expects the `stacked_keys` state entries /data/weights
    stacked over the device axes (leading K == prod of device-axis
    sizes).

    The jitted shard_map closure is built once on first call and cached,
    so repeated per-round dispatches pay dispatch latency only — this is
    the baseline the fused scans are benchmarked against. It runs the
    SAME per-slice round math (including the averaging impl, pallas by
    default), so the driver bench isolates pure dispatch overhead.
    """
    axis = device_axes

    def round_body(state, data_local, weight_local, round_key):
        # inside shard_map: leading stacked axis has local size 1
        my_index = jax.lax.axis_index(axis)
        data_k = jax.tree.map(lambda x: x[0], data_local)
        st = _unstack_state(state, stacked_keys)
        w_k = weight_local[0]
        weights = jax.lax.all_gather(w_k, axis)
        wsum = jax.lax.psum(w_k.astype(jnp.float32), axis)
        new_st, metrics = slice_round_fn(avg_impl, my_index, st, data_k,
                                         w_k, weights, wsum, round_key)
        return _restack_state(new_st, stacked_keys), metrics

    stacked, rep = P(device_axes), P()
    cache = {}

    def run(state, data_stacked, weights, round_key):
        if "fn" not in cache:
            in_specs = (
                rules.shard_round_state_specs(state, device_axes,
                                              stacked_keys),
                rules.tree_specs(data_stacked, stacked),
                stacked,
                rep,
            )
            out_specs = (
                rules.shard_round_state_specs(state, device_axes,
                                              stacked_keys),
                {name: rep for name in metric_names},
            )
            cache["fn"] = jax.jit(_shard_map(
                round_body, mesh=mesh, in_specs=in_specs,
                out_specs=out_specs))
        return cache["fn"](state, data_stacked, weights, round_key)

    return run


def shard_map_round(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                    device_axes=("data",), avg_impl: str = "pallas"):
    """Single proposed-protocol round per dispatch (the mesh oracle)."""
    return _mesh_single_round(
        partial(_proposed_slice_round, spec, pcfg, device_axes),
        PROPOSED_STACKED_KEYS, PROPOSED_METRICS, mesh, device_axes,
        avg_impl)


def fedgan_shard_map_round(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                           device_axes=("data",),
                           avg_impl: str = "pallas"):
    """Single FedGAN round per dispatch (the mesh FedGAN oracle).
    Expects gen_opt AND disc_opt stacked (every device trains both
    nets)."""
    return _mesh_single_round(
        partial(_fedgan_slice_round, spec, pcfg, device_axes),
        FEDGAN_STACKED_KEYS, FEDGAN_METRICS, mesh, device_axes, avg_impl)


# ---------------------------------------------------------------------------
# Fused multi-round scan INSIDE shard_map — R rounds per dispatch
# ---------------------------------------------------------------------------

def _mesh_rounds_scan(slice_round_fn: Callable, stacked_keys, metric_names,
                      pcfg: ProtocolConfig, mesh, n_rounds: int, *, channel,
                      scheduler, device_axes, disc_step_flops: float,
                      gen_step_flops: float, uplink_bits: Optional[int],
                      avg_impl: str, fedgan: bool,
                      eval_fn: Optional[Callable], eval_every: int):
    """The unified fused round engine on the MESH layout, parametrized
    by the algorithm's per-slice round body.

    Builds `run(state, sched_carry, data_stacked, key, start_round) ->
    (state, sched_carry, out)` — the exact chunk signature of the
    stacked layout's `engine.Trainer._chunk_fn`, with state and
    scheduler carry donated. `out` stacks per-round {"metrics",
    "wallclock_s", "mask", "weights"[, "fid", "fid_eval"]} exactly like
    `protocol.rounds_scan`.

    Everything runs INSIDE shard_map: scheduling and channel timing are
    replicated per-slice computation (deterministic given the round key,
    so every slice agrees without a collective), local updates touch no
    collective, the quantized uplink uses the slice's axis index as its
    device key, and the averaging is `weighted_average_psum` — by
    default `impl="pallas"`: one all-gather of the flat payload + one
    Pallas `wavg` kernel per round (interpret-mode on CPU hosts).

    channel:   core.jax_channel.JaxChannel over K = prod(device axes)
    scheduler: core.jax_scheduling.JaxScheduler
    fedgan:    selects the FedGAN timing/wallclock composition and the
        two-net default uplink payload size
    eval_fn:   optional JITTABLE (gen_params, t, key) -> scalar run
        in-scan via lax.cond on rounds where (t+1) % eval_every == 0
        (replicated — gen is replicated, so every slice evaluates the
        same FID).
    """
    axis = device_axes

    def body(state, sched_carry, data_local, key, start_round):
        my_index = jax.lax.axis_index(axis)
        data_k = jax.tree.map(lambda x: x[0], data_local)
        st = _unstack_state(state, stacked_keys)
        disc_nparams = count_params(st["disc"])
        gen_nparams = count_params(st["gen"])
        bits = uplink_bits
        if bits is None:
            bits = uplink_payload_bits(st, pcfg, fedgan=fedgan)

        def round_body(carry, t):
            st, sc = carry
            round_key = jax.random.fold_in(key, t)

            # Step 1 + channel accounting: same helper (same salts, same
            # draw order) as the stacked layout — masks are bitwise
            # identical across layouts and vs the host oracle.
            mask, sc, timing, weights = schedule_and_time(
                pcfg, channel, scheduler, sc, round_key,
                disc_nparams=disc_nparams, gen_nparams=gen_nparams,
                disc_step_flops=disc_step_flops,
                gen_step_flops=gen_step_flops, fedgan=fedgan,
                uplink_bits=bits)
            w_k = weights[my_index]

            new_st, metrics = slice_round_fn(avg_impl, my_index, st,
                                             data_k, w_k, weights,
                                             weights.sum(), round_key)

            wall = jax_channel.round_wallclock(timing, mask,
                                               schedule=pcfg.schedule,
                                               fedgan=fedgan)
            out = {"metrics": metrics, "wallclock_s": wall, "mask": mask,
                   "weights": weights}
            if eval_fn is not None and eval_every > 0:
                do_eval = (t + 1) % eval_every == 0
                out["fid"] = jax.lax.cond(
                    do_eval,
                    lambda g: jnp.float32(eval_fn(g, t, key)),
                    lambda g: jnp.float32(jnp.nan), new_st["gen"])
                out["fid_eval"] = do_eval
            return (new_st, sc), out

        rounds = jnp.asarray(start_round) + jnp.arange(n_rounds)
        (st, sched_carry), out = jax.lax.scan(round_body,
                                              (st, sched_carry), rounds)
        return _restack_state(st, stacked_keys), sched_carry, out

    stacked, rep = P(device_axes), P()
    cache = {}

    def run(state, sched_carry, data_stacked, key, start_round):
        if "fn" not in cache:
            state_specs = rules.shard_round_state_specs(state, device_axes,
                                                        stacked_keys)
            out_round = {"metrics": {name: rep for name in metric_names},
                         "wallclock_s": rep, "mask": rep, "weights": rep}
            if eval_fn is not None and eval_every > 0:
                out_round["fid"] = rep
                out_round["fid_eval"] = rep
            in_specs = (state_specs,
                        rules.tree_specs(sched_carry, rep),
                        rules.tree_specs(data_stacked, stacked),
                        rep, rep)
            out_specs = (state_specs,
                         rules.tree_specs(sched_carry, rep),
                         out_round)
            cache["fn"] = jax.jit(
                _shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs),
                donate_argnums=(0, 1))
        return cache["fn"](state, sched_carry, data_stacked, key,
                           start_round)

    return run


def shard_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                      n_rounds: int, *, channel, scheduler,
                      device_axes=("data",), disc_step_flops: float = 1e9,
                      gen_step_flops: float = 1e9,
                      uplink_bits: Optional[int] = None,
                      avg_impl: str = "pallas",
                      eval_fn: Optional[Callable] = None,
                      eval_every: int = 0):
    """R fused rounds of the PROPOSED protocol on the mesh layout
    (see `_mesh_rounds_scan`), keyed bitwise-identically to
    `protocol.gan_rounds_scan`."""
    return _mesh_rounds_scan(
        partial(_proposed_slice_round, spec, pcfg, device_axes),
        PROPOSED_STACKED_KEYS, PROPOSED_METRICS, pcfg, mesh, n_rounds,
        channel=channel, scheduler=scheduler, device_axes=device_axes,
        disc_step_flops=disc_step_flops, gen_step_flops=gen_step_flops,
        uplink_bits=uplink_bits, avg_impl=avg_impl, fedgan=False,
        eval_fn=eval_fn, eval_every=eval_every)


def fedgan_shard_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, mesh,
                             n_rounds: int, *, channel, scheduler,
                             device_axes=("data",),
                             disc_step_flops: float = 1e9,
                             gen_step_flops: float = 1e9,
                             uplink_bits: Optional[int] = None,
                             avg_impl: str = "pallas",
                             eval_fn: Optional[Callable] = None,
                             eval_every: int = 0):
    """R fused FEDGAN rounds on the mesh layout: per-device joint D+G
    local iterations, the single two-net quantized uplink payload,
    Algorithm-2-style averaging of BOTH networks, and the FedGAN
    wall-clock composition — one donated shard_map `lax.scan` dispatch,
    keyed bitwise-identically to `fedgan.fedgan_rounds_scan` so the
    host oracle pins it."""
    return _mesh_rounds_scan(
        partial(_fedgan_slice_round, spec, pcfg, device_axes),
        FEDGAN_STACKED_KEYS, FEDGAN_METRICS, pcfg, mesh, n_rounds,
        channel=channel, scheduler=scheduler, device_axes=device_axes,
        disc_step_flops=disc_step_flops, gen_step_flops=gen_step_flops,
        uplink_bits=uplink_bits, avg_impl=avg_impl, fedgan=True,
        eval_fn=eval_fn, eval_every=eval_every)

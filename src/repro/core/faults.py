"""Hostile-worker fault injection — the paper's actual deployment regime.

The paper motivates the protocol with unreliable, heterogeneous,
possibly adversarial edge devices; this module makes worker failure a
first-class, fused-scan-compatible axis of the round engine. A
`FaultConfig` describes a worker population, a `FaultProgram` realizes
it:

  * STATIC ROLES — which workers are free-riders / byzantine and each
    worker's compute slowdown are drawn ONCE, host-side, from
    `numpy.default_rng(cfg.seed)` (the population doesn't change
    between rounds — a compromised device stays compromised). The
    role arrays are plain constants inside every jitted engine.
  * PER-ROUND REALIZATIONS — dropout masks and byzantine noise are
    keyed from the SAME per-round `round_key` machinery as
    `protocol.schedule_and_time` (fresh salts `_SALT_DROP` /
    `_SALT_BYZ`), so identical fault masks realize BITWISE on the host
    oracle, the stacked fused scan, and the mesh `shard_rounds_scan`.
    There is no evolving fault RNG carry: every draw is a pure
    function of (cfg, round_key), which is what makes checkpoint
    resume under faults exact.

Fault axes:

  dropout_prob     — per-round iid worker dropout (partial
                     participation beyond the scheduler's choice): the
                     device answered the schedule but never uploads.
                     Applied to the scheduling mask BEFORE channel
                     timing, so upload timing and wallclock see the
                     true participating set.
  straggler_factor — heterogeneous compute: worker k's local step time
                     is multiplied by slowdown_k ~ U[1, factor] (drawn
                     once), fed into `channel.round_timing` via
                     `compute_mult` so slow workers really do straggle
                     past the deadline and stretch the wallclock.
  n_free_riders    — workers that do NO local training and upload a
                     STALE copy of the global model instead (the
                     free-rider attack against MD-GAN-style servers):
                     the replayed payload is the round-START global
                     parameters cached in `state["fault"]["stale"]`,
                     i.e. what the worker last received. The cache
                     rides inside the training state, so it is donated
                     through the fused scans, replicated by the mesh
                     state specs, and serialized by checkpoints
                     (resume under faults is exact). Free-riders spend
                     no compute (compute_mult 0) — they answer
                     instantly and never straggle on compute.
  n_byzantine      — workers that upload scaled Gaussian noise
                     (`byz_scale` x N(0, 1), one flat draw over the
                     payload sliced per leaf — the same draw-order
                     trick as `quantize.quantize_tree`, so stacked
                     vmap and mesh per-slice execution corrupt
                     bitwise-identically).

Free-rider and byzantine roles are disjoint (drawn from one
permutation). Counter the corruption with the robust reducers in
`kernels/robust_avg` via `engine.Trainer(reducer=...)`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# PRNG salts for the per-round fault streams, disjoint from the
# protocol (_SALT_SHARED_Z/_SALT_DATA), channel (_SALT_RATES/_SALT_SCHED/
# _SALT_TIMING), and quantizer (_SALT_QUANT) salts.
_SALT_DROP = 0xD120FF
_SALT_BYZ = 0xB42A27


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Hostile-worker population description (hashable: it is part of
    the mesh builder memo keys and the engine's chunk-fn cache keys)."""
    n_devices: int
    dropout_prob: float = 0.0
    n_free_riders: int = 0
    n_byzantine: int = 0
    byz_scale: float = 10.0
    straggler_factor: float = 1.0
    seed: int = 0

    def __post_init__(self):
        # dropout_prob=1.0 is legal: every round is a no-survivor round
        # and the globals stay frozen (averaging's fallback semantics) —
        # the degenerate regime tests/test_no_survivor.py pins.
        if not 0.0 <= self.dropout_prob <= 1.0:
            raise ValueError(
                f"dropout_prob must be in [0, 1] (got {self.dropout_prob})")
        if self.n_free_riders < 0 or self.n_byzantine < 0:
            raise ValueError("n_free_riders/n_byzantine must be >= 0")
        if self.n_free_riders + self.n_byzantine > self.n_devices:
            raise ValueError(
                f"{self.n_free_riders} free-riders + {self.n_byzantine} "
                f"byzantine workers exceed n_devices={self.n_devices}")
        if self.straggler_factor < 1.0:
            raise ValueError(
                f"straggler_factor must be >= 1 (got "
                f"{self.straggler_factor}) — it multiplies compute time")

    @property
    def corrupts_uploads(self) -> bool:
        return self.n_free_riders > 0 or self.n_byzantine > 0


class FaultProgram:
    """Realized fault program: static role arrays + per-round keyed
    draws. Build through `fault_program(cfg)` (memoized — the arrays
    are baked as constants into jitted round functions)."""

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        perm = rng.permutation(cfg.n_devices)
        free_rider = np.zeros(cfg.n_devices, bool)
        free_rider[perm[:cfg.n_free_riders]] = True
        byzantine = np.zeros(cfg.n_devices, bool)
        byzantine[perm[cfg.n_free_riders:
                       cfg.n_free_riders + cfg.n_byzantine]] = True
        slowdown = rng.uniform(1.0, cfg.straggler_factor,
                               cfg.n_devices) if cfg.straggler_factor > 1.0 \
            else np.ones(cfg.n_devices)
        # free-riders train nothing: zero local compute time
        compute_mult = np.where(free_rider, 0.0, slowdown)

        self.free_rider_np = free_rider
        self.byzantine_np = byzantine
        self.compute_mult_np = compute_mult.astype(np.float64)
        # the first fault_program() call may happen INSIDE a trace (the
        # launch-path builders construct lazily); force the role arrays
        # to concrete constants or the memoized program would leak
        # tracers into later traces
        with jax.ensure_compile_time_eval():
            self.free_rider = jnp.asarray(free_rider)
            self.byzantine = jnp.asarray(byzantine)
            self.compute_mult = jnp.asarray(compute_mult, jnp.float32)

    @property
    def corrupts(self) -> bool:
        return self.cfg.corrupts_uploads

    # ------------------------------------------------------------------
    # per-round realizations — pure functions of round_key
    # ------------------------------------------------------------------
    def dropout_mask(self, round_key):
        """(K,) bool — True where the worker DROPS this round. Keyed by
        `fold_in(round_key, _SALT_DROP)`; the ONE definition every
        engine (host numpy loop included, via np.asarray of this) uses,
        so dropout is bitwise-identical across layouts and drivers."""
        if self.cfg.dropout_prob <= 0.0:
            return jnp.zeros(self.cfg.n_devices, bool)
        u = jax.random.uniform(jax.random.fold_in(round_key, _SALT_DROP),
                               (self.cfg.n_devices,))
        return u < self.cfg.dropout_prob

    def dropout_mask_np(self, round_key) -> np.ndarray:
        """Host-oracle twin: the SAME jax draw, materialized to numpy."""
        return np.asarray(self.dropout_mask(round_key))


def byz_key(round_key, dev_index):
    """Key for device `dev_index`'s byzantine noise this round — one
    definition shared by the stacked vmap and the mesh slice paths
    (mirrors `quantize.device_uplink_key`)."""
    return jax.random.fold_in(jax.random.fold_in(round_key, _SALT_BYZ),
                              dev_index)


def byzantine_noise(key, payload, scale: float):
    """Scaled-Gaussian forged payload with the payload's structure.

    ONE flat normal draw over the whole payload sliced per leaf (the
    `quantize.quantize_tree` draw-order trick): the realized noise is
    independent of how the tree is traversed, so every execution layout
    forges bitwise-identical uploads."""
    leaves, treedef = jax.tree_util.tree_flatten(payload)
    sizes = [int(x.size) for x in leaves]
    flat = jax.random.normal(key, (sum(sizes),)) * scale
    out, off = [], 0
    for x, size in zip(leaves, sizes):
        out.append(flat[off:off + size].reshape(x.shape).astype(x.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def corrupt_upload(prog: FaultProgram, round_key, dev_index, payload,
                   stale=None):
    """Device `dev_index`'s ACTUAL upload under the fault program:
    the honest `payload`, the `stale` cached global (free-rider), or
    scaled noise (byzantine). Pure jnp.where selection so `dev_index`
    may be traced (mesh slice) or vmapped (stacked layout) — identical
    math either way."""
    cfg = prog.cfg
    out = payload
    if cfg.n_free_riders > 0 and stale is not None:
        is_fr = prog.free_rider[dev_index]
        out = jax.tree.map(lambda p, s: jnp.where(is_fr, s, p), out, stale)
    if cfg.n_byzantine > 0:
        is_byz = prog.byzantine[dev_index]
        noise = byzantine_noise(byz_key(round_key, dev_index), payload,
                                cfg.byz_scale)
        out = jax.tree.map(lambda p, n: jnp.where(is_byz, n, p), out, noise)
    return out


def corrupt_uploads_stacked(prog: FaultProgram, round_key, payload_stacked,
                            stale=None):
    """Stacked-layout twin of `corrupt_upload`: apply the fault program
    to a payload pytree with leading device axis K. `stale` is the
    UNSTACKED cached global payload (same copy for every free-rider)."""
    n_devices = prog.cfg.n_devices
    fn = lambda i, p: corrupt_upload(prog, round_key, i, p, stale)
    return jax.vmap(fn, in_axes=(0, 0))(jnp.arange(n_devices),
                                        payload_stacked)


def attach_fault_state(state, faults: FaultConfig | None, payload_fn):
    """Seed the stale-upload cache into a fresh training state when the
    fault program has free-riders: `state["fault"]["stale"]` holds the
    round-start global payload (`payload_fn(state)`, e.g.
    `shard_round.PROPOSED_PAYLOAD`). The entry is a regular state key:
    non-stacked, so `rules.shard_round_state_specs` replicates it on
    the mesh, the fused scans carry it, and checkpoints serialize it —
    resume under faults reproduces the replayed uploads exactly."""
    if faults is None or faults.n_free_riders == 0 or payload_fn is None:
        return state
    state = dict(state)
    # jnp.array COPIES: the cache must not alias the live parameter
    # buffers, or the fused drivers' donation sees one buffer twice.
    state["fault"] = {"stale": jax.tree.map(jnp.array, payload_fn(state))}
    return state


# FaultConfig -> FaultProgram memo: programs hold device arrays that
# jitted round functions close over as constants; rebuilding per trace
# would defeat the builder/chunk caches' reuse.
_PROGRAMS: dict = {}


def fault_program(cfg: FaultConfig | None) -> FaultProgram | None:
    if cfg is None:
        return None
    prog = _PROGRAMS.get(cfg)
    if prog is None:
        prog = _PROGRAMS[cfg] = FaultProgram(cfg)
    return prog

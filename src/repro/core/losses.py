"""GAN objectives — the paper's equations (1) and (2).

The paper defines (discriminator outputs a probability D; we work with
logits and use numerically stable softplus forms):

  g_theta(theta, phi, z)    = grad_theta log(1 - D(phi, G(theta, z)))      (1)
  g_phi(theta, phi, z, x)   = grad_phi [log D(phi, x)
                                        + log(1 - D(phi, G(theta, z)))]    (2)

Algorithm 1 *ascends* g_phi (maximize discriminator objective);
Algorithm 3 *descends* g_theta (original minimax generator). A
non-saturating generator loss (-log D(fake)) is available as an opt-in
variant for practical small-scale runs; the faithful default is (1).

With logits l: log D = -softplus(-l), log(1 - D) = -softplus(l).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def log_d(logits):
    return -jax.nn.softplus(-logits)


def log_one_minus_d(logits):
    return -jax.nn.softplus(logits)


def disc_objective(real_logits, fake_logits):
    """Paper eq (2) objective (to MAXIMIZE): E[log D(x)] + E[log(1-D(G(z)))]."""
    return jnp.mean(log_d(real_logits)) + jnp.mean(log_one_minus_d(fake_logits))


def gen_objective_minimax(fake_logits):
    """Paper eq (1) objective (to MINIMIZE): E[log(1-D(G(z)))]."""
    return jnp.mean(log_one_minus_d(fake_logits))


def gen_objective_nonsaturating(fake_logits):
    """-E[log D(G(z))] (to MINIMIZE) — Goodfellow's practical variant."""
    return -jnp.mean(log_d(fake_logits))


def gen_objective(fake_logits, *, variant: str = "minimax"):
    if variant == "minimax":
        return gen_objective_minimax(fake_logits)
    if variant == "nonsaturating":
        return gen_objective_nonsaturating(fake_logits)
    raise ValueError(f"unknown generator loss variant {variant!r}")

"""FedGAN baseline [9] (Rasouli, Sun, Rajagopal, arXiv:2006.07228).

Each device trains BOTH a local generator and a local discriminator for
n local iterations (each iteration: one discriminator ascent step + one
generator descent step on local data); the server only averages the two
parameter sets. Compared with the proposed framework, each device does
~2x the computation per round and uploads ~2x the bytes (theta AND phi)
— the communication/computation asymmetry that Fig. 5 measures.

`fedgan_rounds_scan` runs R FedGAN rounds per XLA dispatch through the
same unified engine (`protocol.rounds_scan`) as the proposed protocol:
scheduling, channel timing with the FedGAN wallclock composition, the
quantized two-net uplink, and optional in-scan FID are all one
`lax.scan`. The per-round host loop in `core.engine` stays the oracle.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import faults as faults_lib
from repro.core import losses, quantize
from repro.core.averaging import weighted_average, broadcast_like
from repro.core.protocol import (GanModelSpec, rounds_scan,
                                 _SALT_SHARED_Z, _SALT_DATA)
from repro.optim import make_optimizer, apply_updates


def fedgan_device_update(spec: GanModelSpec, pcfg: ProtocolConfig,
                         gen0, disc0, gen_opt, disc_opt, data_local,
                         round_key, dev_index):
    """n_d local iterations of (disc step, gen step) on device data."""
    n_local = jax.tree_util.tree_leaves(data_local)[0].shape[0]
    m = pcfg.sample_size
    d_opt = make_optimizer(pcfg.optimizer, pcfg.lr_d)
    g_opt = make_optimizer(pcfg.optimizer, pcfg.lr_g)

    def one_iter(carry, j):
        gen, disc, g_state, d_state = carry
        kz = jax.random.fold_in(jax.random.fold_in(round_key, _SALT_SHARED_Z), j)
        kx = jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(round_key, _SALT_DATA),
                               dev_index), j)
        idx = jax.random.randint(kx, (m,), 0, n_local)
        x = jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data_local)
        z = spec.sample_z(kz, m)

        # discriminator ascent on eq (2)
        fake = spec.gen_apply(gen, z)

        def neg_obj(phi):
            return -losses.disc_objective(spec.disc_real(phi, x),
                                          spec.disc_fake(phi, fake))

        d_grads = jax.grad(neg_obj)(disc)
        d_updates, d_state = d_opt.update(d_grads, d_state, disc)
        disc = apply_updates(disc, d_updates)

        # generator descent on eq (1) against the freshly updated disc
        def gen_obj(theta):
            f = spec.gen_apply(theta, z)
            return losses.gen_objective(spec.disc_fake(disc, f),
                                        variant=spec.gen_loss_variant)

        g_grads = jax.grad(gen_obj)(gen)
        g_updates, g_state = g_opt.update(g_grads, g_state, gen)
        gen = apply_updates(gen, g_updates)
        return (gen, disc, g_state, d_state), None

    (gen, disc, g_state, d_state), _ = jax.lax.scan(
        one_iter, (gen0, disc0, gen_opt, disc_opt), jnp.arange(pcfg.n_d))
    return gen, disc, g_state, d_state


def fedgan_round(spec: GanModelSpec, pcfg: ProtocolConfig, state,
                 data_stacked, weights, round_key, *, faults=None,
                 reducer=None):
    """One FedGAN communication round: local joint updates, average BOTH
    generators and discriminators (server does model averaging only).
    `faults`/`reducer` mirror `protocol.gan_round`: corruption hits the
    COMBINED {"gen", "disc"} payload after the quantized uplink, and the
    robust reducer aggregates that combined tree in ONE reduction
    (matching the mesh layout's single-payload hot path) before the two
    nets are split back out."""
    n_devices = weights.shape[0]
    gen_stacked = broadcast_like(state["gen"], n_devices)
    disc_stacked = broadcast_like(state["disc"], n_devices)

    dev_fn = jax.vmap(
        lambda g, d, go, do, x, i: fedgan_device_update(
            spec, pcfg, g, d, go, do, x, round_key, i),
        in_axes=(0, 0, 0, 0, 0, 0))
    new_gens, new_discs, new_gen_opt, new_disc_opt = dev_fn(
        gen_stacked, disc_stacked, state["gen_opt"], state["disc_opt"],
        data_stacked, jnp.arange(n_devices))

    # FedGAN uploads BOTH nets in one payload — quantized as a single
    # tree per device (one stochastic-rounding draw per upload), keyed
    # from round_key alone so the host oracle and the fused engine
    # quantize bitwise-identically.
    payload = quantize.roundtrip_stacked(
        round_key, {"gen": new_gens, "disc": new_discs},
        pcfg.quantize_bits)

    prog = faults_lib.fault_program(faults)
    if prog is not None and prog.corrupts:
        stale = state["fault"]["stale"] if "fault" in state else None
        payload = faults_lib.corrupt_uploads_stacked(
            prog, round_key, payload, stale=stale)

    # No-survivor rounds keep the previous globals (see protocol.gan_round).
    prev = {"gen": state["gen"], "disc": state["disc"]}
    if reducer is not None:
        avg = weighted_average(payload, weights, robust=reducer,
                               fallback=prev)
        gen_avg, disc_avg = avg["gen"], avg["disc"]
    else:
        gen_avg = weighted_average(payload["gen"], weights,
                                   fallback=prev["gen"])
        disc_avg = weighted_average(payload["disc"], weights,
                                    fallback=prev["disc"])
    new_state = {"gen": gen_avg, "disc": disc_avg,
                 "gen_opt": new_gen_opt, "disc_opt": new_disc_opt}
    if "fault" in state:
        new_state["fault"] = {"stale": {"gen": state["gen"],
                                        "disc": state["disc"]}}
    return new_state, {"participation": (weights > 0).astype(jnp.float32).mean()}


def fedgan_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, state,
                       data_stacked, key, n_rounds: int, *,
                       channel, scheduler, sched_carry=None, start_round=0,
                       disc_step_flops: float = 1e9,
                       gen_step_flops: float = 1e9,
                       uplink_bits: Optional[int] = None,
                       eval_fn: Optional[Callable] = None,
                       eval_every: int = 0, faults=None, reducer=None):
    """R fused FedGAN rounds (see `protocol.rounds_scan`): the baseline
    gets the same one-dispatch-per-chunk engine as the proposed
    protocol, with `fedgan=True` selecting the two-net upload payload
    and the Fig. 5 wallclock composition."""
    round_fn = lambda st, d, w, k: fedgan_round(spec, pcfg, st, d, w, k,
                                                faults=faults,
                                                reducer=reducer)
    return rounds_scan(round_fn, pcfg, state, data_stacked, key, n_rounds,
                       channel=channel, scheduler=scheduler,
                       sched_carry=sched_carry, start_round=start_round,
                       disc_step_flops=disc_step_flops,
                       gen_step_flops=gen_step_flops, fedgan=True,
                       uplink_bits=uplink_bits, eval_fn=eval_fn,
                       eval_every=eval_every, faults=faults)


def make_fedgan_state(key, init_fn, pcfg: ProtocolConfig, n_devices: int):
    params = init_fn(key)
    g_opt = make_optimizer(pcfg.optimizer, pcfg.lr_g).init(params["gen"])
    d_opt = make_optimizer(pcfg.optimizer, pcfg.lr_d).init(params["disc"])
    return {"gen": params["gen"], "disc": params["disc"],
            "gen_opt": broadcast_like(g_opt, n_devices),
            "disc_opt": broadcast_like(d_opt, n_devices)}

"""Pure-JAX twin of `core.channel` (paper Section IV wireless system).

Device placement is drawn host-side with the SAME numpy seed as
`ChannelSimulator`, so a `JaxChannel(cfg)` sees the exact distances (and
hence path losses and the deterministic downlink rate) of its numpy
twin. Per-round Rayleigh fading uses `jax.random.exponential` — the same
Exp(1) marginal as the numpy stream but different draws, so fading
quantities agree in distribution, not bitwise. With `fading=False` every
output matches the numpy simulator to float32 round-off, which is the
oracle contract tests/test_driver_equivalence.py pins down.

All methods are pure and jittable; the fused driver calls them inside
`lax.scan` with per-round keys.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig, ChannelSimulator


class JaxRoundTiming(NamedTuple):
    compute_dev_s: jnp.ndarray     # (K,) local discriminator compute
    upload_s: jnp.ndarray          # (K,) local model upload
    compute_srv_s: jnp.ndarray     # scalar — generator update
    broadcast_s: jnp.ndarray       # scalar — global model broadcast
    stragglers: jnp.ndarray        # (K,) bool — missed the deadline


class JaxChannel:
    """Jittable channel simulator over a fixed device placement."""

    def __init__(self, cfg: ChannelConfig):
        self.cfg = cfg
        # Delegate placement, path loss, and the fading-free downlink
        # rate to the numpy twin (all host-side f64), so the two
        # simulators share one definition of the cell layout.
        sim = ChannelSimulator(cfg)
        self.dist_km = jnp.asarray(sim.dist_km, jnp.float32)
        self.gain = jnp.asarray(10.0 ** (-sim.path_loss_db() / 10.0),
                                jnp.float32)
        self.downlink_rate_s = sim.downlink_rate()

    def path_loss_db(self):
        return 128.1 + 37.6 * jnp.log10(self.dist_km)

    def uplink_rates(self, key, n_scheduled):
        """(K,) bits/s under an equal OFDMA split of the band.
        n_scheduled may be a static int or a traced scalar (mask.sum())."""
        cfg = self.cfg
        bw = cfg.bandwidth_hz / jnp.maximum(
            jnp.asarray(n_scheduled, jnp.float32), 1.0)
        noise_w = 10 ** ((cfg.noise_psd_dbm_hz - 30) / 10) * bw
        tx_w = 10 ** ((cfg.device_tx_dbm - 30) / 10)
        gain = self.gain
        if cfg.fading:
            gain = gain * jax.random.exponential(key, (cfg.n_devices,))
        snr = tx_w * gain / noise_w
        return bw * jnp.log2(1.0 + snr)

    # ------------------------------------------------------------------
    def round_timing(self, key, mask, *, disc_params: int, gen_params: int,
                     disc_step_flops: float, gen_step_flops: float,
                     n_d: int, n_g: int, fedgan: bool = False,
                     uplink_bits: float | None = None,
                     compute_mult=None) -> JaxRoundTiming:
        """Wall-clock pieces of one communication round (fresh fading
        draw, mirroring the numpy twin's second `uplink_rates` call).
        `uplink_bits` overrides the per-device upload payload exactly as
        in the numpy twin; `compute_mult` is the optional (K,)
        per-device local-compute multiplier (core/faults.py)."""
        cfg = self.cfg
        rates = self.uplink_rates(key, jnp.sum(mask))
        up_bits = uplink_bits if uplink_bits is not None else (
            cfg.bits_per_param * (
                disc_params + gen_params if fedgan else disc_params))
        upload = jnp.where(mask, up_bits / jnp.maximum(rates, 1.0), 0.0)
        dev_flops = n_d * disc_step_flops + (
            n_g * gen_step_flops if fedgan else 0.0)
        compute_dev = jnp.where(mask, dev_flops / cfg.device_flops, 0.0)
        if compute_mult is not None:
            compute_dev = compute_dev * jnp.asarray(compute_mult, jnp.float32)
        compute_srv = jnp.float32(
            0.0 if fedgan else n_g * gen_step_flops / cfg.server_flops)
        down_bits = cfg.bits_per_param * (disc_params + gen_params)
        broadcast = jnp.float32(down_bits / self.downlink_rate_s)
        stragglers = mask & (upload + compute_dev > cfg.straggler_deadline_s)
        return JaxRoundTiming(compute_dev, upload, compute_srv, broadcast,
                              stragglers)


def round_wallclock(t: JaxRoundTiming, mask, *, schedule: str,
                    fedgan: bool = False):
    """Fig. 1 / Fig. 2 wall-clock composition, jittable twin of
    `channel.round_wallclock`. Returns a float32 scalar."""
    active = mask & ~t.stragglers
    any_active = active.any()

    def masked_max(x):
        return jnp.max(jnp.where(active, x, -jnp.inf))

    if fedgan:
        wall = masked_max(t.compute_dev_s + t.upload_s) + t.broadcast_s
    elif schedule == "parallel":
        wall = (jnp.maximum(masked_max(t.compute_dev_s), t.compute_srv_s)
                + masked_max(t.upload_s) + t.broadcast_s)
    elif schedule == "serial":
        wall = (masked_max(t.compute_dev_s + t.upload_s)
                + jnp.maximum(t.compute_srv_s, t.broadcast_s * 0.5)
                + t.broadcast_s * 0.5)
    else:
        raise ValueError(schedule)
    return jnp.where(any_active, wall, t.broadcast_s).astype(jnp.float32)

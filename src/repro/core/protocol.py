"""THE PAPER'S CONTRIBUTION — the distributed GAN training protocol.

One communication round (Section II-B, Section III):

  Step 1  server schedules S ⊆ K devices          (core.scheduling, host)
  Step 2  scheduled devices run Algorithm 1 (n_d local discriminator SGD
          steps); under the PARALLEL schedule the server simultaneously
          runs Algorithm 3 from the same round-start parameters, with
          shared-seed noise
  Step 3  devices upload local discriminators     (16-bit, core.quantize)
  Step 4  server averages them — Algorithm 2      (core.averaging)
  Step 5  server broadcasts the global GAN
  SERIAL schedule: Algorithm 3 runs after Step 4 against the fresh
          global discriminator.

`gan_round` is a pure jittable function: the paper's K devices appear as
a stacked leading axis, so the SAME code runs (a) on CPU for the
paper-scale experiments and (b) under pjit on the production mesh where
the stacked axis is sharded over ("pod","data") and Algorithm 2's
weighted mean lowers to the ICI all-reduce (DESIGN.md §2).

The model is abstracted by `GanModelSpec`, so DCGAN (the paper's
experiment) and every assigned backbone-GAN use one protocol
implementation.

FUSED MULTI-ROUND ENGINE: `rounds_scan` folds R complete rounds of ANY
round function — Step 1 scheduling (core.jax_scheduling), channel
timing + straggler exclusion (core.jax_channel) with the actual
quantized payload size, the round's model math (with the Step 3
quantized uplink inside), optional IN-SCAN FID via `lax.cond`, and the
Fig. 1/Fig. 2 wall-clock composition — into a single `lax.scan`, so one
XLA dispatch advances R communication rounds and returns stacked
per-round metrics/wallclock/masks[/fid]. `gan_rounds_scan` instantiates
it for the proposed protocol and `fedgan.fedgan_rounds_scan` for the
FedGAN baseline (Fig. 5's comparison runs both fused). The host-side
per-round loop in `core.engine.Trainer(driver="host")` is retained as
the equivalence ORACLE: for deterministic schedulers (or
`fading=False`) the fused path must reproduce its masks bitwise and its
params/metrics to float32 round-off (tests/test_driver_equivalence.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import faults as faults_lib
from repro.core import jax_channel, jax_scheduling, losses, quantize
from repro.core.averaging import weighted_average, broadcast_like
from repro.optim import make_optimizer, apply_updates
from repro.optim.optimizers import tree_add


def _accumulated_grad(loss_fn, params, batch_axis_trees, total: int,
                      micro: Optional[int]):
    """value_and_grad with gradient accumulation over microbatches.

    loss_fn(params, *slices) -> scalar mean loss over the slice.
    batch_axis_trees: pytrees whose leaves have leading axis `total`,
    sliced jointly into `total // micro` chunks.
    """
    if micro is None or micro >= total:
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch_axis_trees)
        return loss, grads
    assert total % micro == 0, f"micro {micro} must divide batch {total}"
    n_chunks = total // micro

    def chunk(i, tree):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, i * micro, micro,
                                                   axis=0), tree)

    def body(carry, i):
        loss_acc, grad_acc = carry
        slices = [chunk(i, t) for t in batch_axis_trees]
        loss, grads = jax.value_and_grad(loss_fn)(params, *slices)
        return (loss_acc + loss, tree_add(grad_acc, grads)), None

    zeros = jax.tree.map(jnp.zeros_like, params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), jnp.arange(n_chunks))
    scale = 1.0 / n_chunks
    return loss_sum * scale, jax.tree.map(lambda g: g * scale, grad_sum)

# PRNG salts: the SHARED noise stream (paper: "identical pseudo random
# sequence" between server and devices) vs device-private data sampling.
_SALT_SHARED_Z = 0x5EED
_SALT_DATA = 0xDA7A


@dataclasses.dataclass(frozen=True)
class GanModelSpec:
    """Adapter between the protocol and a concrete (G, D) pair.

    sample_z(key, n)                 -> noise batch
    gen_apply(gen_params, z)         -> fake data batch
    disc_real(disc_params, batch)    -> logits (n,) on real data
    disc_fake(disc_params, fake)     -> logits (n,) on generated data

    tp_axis: set by TP-aware builders (`make_backbone_spec(tp_axis=)`,
    `gan.mlp_gan_spec(tp_axis=)`) when the apply functions contain
    in-slice Megatron collectives over that manual mesh axis — the
    params they receive must then be model-axis SHARDS. The mesh
    engine validates this against its own tp setting
    (`engine.Trainer(tp=)`), because a mismatch computes silently
    wrong results: a dense spec consumes shards shape-consistently but
    never psums the partial products.
    """
    sample_z: Callable
    gen_apply: Callable
    disc_real: Callable
    disc_fake: Callable
    gen_loss_variant: str = "minimax"
    tp_axis: Optional[str] = None


def make_train_state(key, init_fn, pcfg: ProtocolConfig, n_devices: int):
    """init_fn(key) -> {"gen": ..., "disc": ...}."""
    params = init_fn(key)
    gen_opt = make_optimizer(pcfg.optimizer, pcfg.lr_g).init(params["gen"])
    disc_opt_one = make_optimizer(pcfg.optimizer, pcfg.lr_d).init(params["disc"])
    # per-device local optimizer state (persists locally, never averaged)
    disc_opt = broadcast_like(disc_opt_one, n_devices)
    return {"gen": params["gen"], "disc": params["disc"],
            "gen_opt": gen_opt, "disc_opt": disc_opt}


# ---------------------------------------------------------------------------
# Algorithm 1 — device k's update
# ---------------------------------------------------------------------------

def device_update(spec: GanModelSpec, pcfg: ProtocolConfig, gen_params,
                  disc_params, disc_opt, data_local, round_key, dev_index):
    """n_d mini-batch steps ascending eq (2) on the LOCAL data shard.

    data_local: pytree with leading axis n_k (the device's private data).
    Fresh samples each step (Algorithm 1 line 5): m_k indices drawn with
    replacement from the local shard; noise from the SHARED stream.
    """
    n_local = jax.tree_util.tree_leaves(data_local)[0].shape[0]
    m = pcfg.sample_size
    opt = make_optimizer(pcfg.optimizer, pcfg.lr_d)

    def one_step(carry, j):
        disc, opt_state = carry
        kz = jax.random.fold_in(jax.random.fold_in(round_key, _SALT_SHARED_Z), j)
        kx = jax.random.fold_in(
            jax.random.fold_in(jax.random.fold_in(round_key, _SALT_DATA),
                               dev_index), j)
        idx = jax.random.randint(kx, (m,), 0, n_local)
        x = jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data_local)
        z = spec.sample_z(kz, m)
        fake = spec.gen_apply(gen_params, z)      # round-start theta

        def neg_obj(phi, x_mb, fake_mb):
            return -losses.disc_objective(spec.disc_real(phi, x_mb),
                                          spec.disc_fake(phi, fake_mb))

        loss, grads = _accumulated_grad(neg_obj, disc, [x, fake], m,
                                        pcfg.micro_batch_d)
        updates, opt_state = opt.update(grads, opt_state, disc)
        disc = apply_updates(disc, updates)       # eq (3): ascent on eq (2)
        return (disc, opt_state), -loss

    (disc, opt_state), objs = jax.lax.scan(
        one_step, (disc_params, disc_opt), jnp.arange(pcfg.n_d))
    return disc, opt_state, objs[-1]


def devices_round_hoisted(spec: GanModelSpec, pcfg: ProtocolConfig,
                          gen_params, disc_stacked, disc_opt_stacked,
                          data_stacked, round_key):
    """Algorithm 1 for ALL devices with the fake batch HOISTED.

    The shared noise stream (Section III-A) makes every device's fake
    batch at local step j identical, so G(theta, z_j) runs ONCE per step
    — batch-shardable over the device axes — instead of once per device.
    Bitwise-identical math to the vmapped path; K x fewer generator
    forwards. Loop order becomes scan-over-steps(vmap-over-devices).
    """
    n_devices = jax.tree_util.tree_leaves(data_stacked)[0].shape[0]
    n_local = jax.tree_util.tree_leaves(data_stacked)[0].shape[1]
    m = pcfg.sample_size
    opt = make_optimizer(pcfg.optimizer, pcfg.lr_d)

    def one_step(carry, j):
        discs, opts = carry
        kz = jax.random.fold_in(jax.random.fold_in(round_key, _SALT_SHARED_Z), j)
        z = spec.sample_z(kz, m)
        fake = spec.gen_apply(gen_params, z)      # once, for every device

        def one_device(disc, opt_state, data_local, dev_index):
            kx = jax.random.fold_in(
                jax.random.fold_in(jax.random.fold_in(round_key, _SALT_DATA),
                                   dev_index), j)
            idx = jax.random.randint(kx, (m,), 0, n_local)
            x = jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data_local)

            def neg_obj(phi, x_mb, fake_mb):
                return -losses.disc_objective(spec.disc_real(phi, x_mb),
                                              spec.disc_fake(phi, fake_mb))

            loss, grads = _accumulated_grad(neg_obj, disc, [x, fake], m,
                                            pcfg.micro_batch_d)
            updates, opt_state = opt.update(grads, opt_state, disc)
            return apply_updates(disc, updates), opt_state, -loss

        discs, opts, objs = jax.vmap(one_device, in_axes=(0, 0, 0, 0))(
            discs, opts, data_stacked, jnp.arange(n_devices))
        return (discs, opts), objs

    (discs, opts), objs = jax.lax.scan(
        one_step, (disc_stacked, disc_opt_stacked), jnp.arange(pcfg.n_d))
    return discs, opts, objs[-1]


# ---------------------------------------------------------------------------
# Algorithm 3 — server generator update
# ---------------------------------------------------------------------------

def server_update(spec: GanModelSpec, pcfg: ProtocolConfig, gen_params,
                  gen_opt, disc_params, round_key):
    """n_g steps descending eq (1) against the given discriminator.
    Uses the SAME shared noise stream as the devices (parallel-schedule
    seed consistency, Section III-A)."""
    M = pcfg.server_sample_size
    opt = make_optimizer(pcfg.optimizer, pcfg.lr_g)

    def one_step(carry, j):
        gen, opt_state = carry
        kz = jax.random.fold_in(jax.random.fold_in(round_key, _SALT_SHARED_Z), j)
        z = spec.sample_z(kz, M)

        def obj(theta, z_mb):
            fake = spec.gen_apply(theta, z_mb)
            return losses.gen_objective(spec.disc_fake(disc_params, fake),
                                        variant=spec.gen_loss_variant)

        loss, grads = _accumulated_grad(obj, gen, [z], M, pcfg.micro_batch_g)
        updates, opt_state = opt.update(grads, opt_state, gen)
        gen = apply_updates(gen, updates)         # eq (4): descent on eq (1)
        return (gen, opt_state), loss

    (gen, gen_opt), objs = jax.lax.scan(
        one_step, (gen_params, gen_opt), jnp.arange(pcfg.n_g))
    return gen, gen_opt, objs[-1]


# ---------------------------------------------------------------------------
# One communication round (Steps 1–5)
# ---------------------------------------------------------------------------

def gan_round(spec: GanModelSpec, pcfg: ProtocolConfig, state, data_stacked,
              weights, round_key, *, constrain_stacked=None, faults=None,
              reducer=None):
    """One full round.

    state: {"gen", "disc", "gen_opt", "disc_opt"} — disc/disc_opt are the
           GLOBAL discriminator (post-broadcast) and the per-device local
           optimizer states (stacked K). An optional "fault" entry holds
           the free-rider stale-upload cache (core/faults.py).
    data_stacked: pytree, leading axes (K, n_k, ...) — device-private shards.
    weights: (K,) — m_k for scheduled devices, 0 otherwise (Step 1 output;
           also encodes straggler exclusion, footnote 1).
    faults:  optional FaultConfig — free-riders replay the stale cache and
           byzantine workers upload scaled noise, keyed by `round_key` so
           every execution layout realizes identical corruption.
    reducer: optional RobustConfig — Step 4 aggregates with the selected
           robust reducer instead of the plain weighted mean.
    Returns (new_state, metrics).
    """
    n_devices = weights.shape[0]
    disc_stacked = broadcast_like(state["disc"], n_devices)  # Step 5 (prev)
    if constrain_stacked is not None:
        # pjit path: pin the per-device replicas to the device mesh axes so
        # GSPMD keeps Algorithm 1 embarrassingly parallel.
        disc_stacked = constrain_stacked(disc_stacked)

    # Step 2 — Algorithm 1 on every device slice (vmapped; on the pod mesh
    # the stacked axis is sharded so each slice computes only its own).
    if pcfg.hoist_fakes:
        new_discs, new_disc_opt, disc_objs = devices_round_hoisted(
            spec, pcfg, state["gen"], disc_stacked, state["disc_opt"],
            data_stacked, round_key)
    else:
        dev_fn = jax.vmap(
            lambda d, o, x, i: device_update(spec, pcfg, state["gen"], d, o,
                                             x, round_key, i),
            in_axes=(0, 0, 0, 0))
        new_discs, new_disc_opt, disc_objs = dev_fn(
            disc_stacked, state["disc_opt"], data_stacked,
            jnp.arange(n_devices))

    # Step 3 — each device quantizes its upload (paper Section IV,
    # 16 bits/param by default; >=32 bits is the float32 identity).
    new_discs = quantize.roundtrip_stacked(round_key, new_discs,
                                           pcfg.quantize_bits)

    # Hostile uploads (core/faults.py): free-riders replay the stale
    # cache, byzantine devices upload scaled noise — applied AFTER the
    # quantized uplink, exactly where the server receives payloads.
    prog = faults_lib.fault_program(faults)
    if prog is not None and prog.corrupts:
        stale = state["fault"]["stale"] if "fault" in state else None
        new_discs = faults_lib.corrupt_uploads_stacked(
            prog, round_key, new_discs, stale=stale)

    # Steps 3–4 — Algorithm 2: weighted averaging (the uplink collective),
    # optionally through a robust reducer (kernels/robust_avg). On a
    # no-survivor round (every weight zero) the previous global
    # discriminator is kept — averaging nothing is not "multiply by ~0".
    disc_avg = weighted_average(new_discs, weights, robust=reducer,
                                fallback=state["disc"])

    # Algorithm 3 — serial: against fresh phi^{t+1}; parallel: against the
    # round-start phi^t, dataflow-independent of the averaging collective.
    disc_for_gen = disc_avg if pcfg.schedule == "serial" else state["disc"]
    new_gen, new_gen_opt, gen_obj = server_update(
        spec, pcfg, state["gen"], state["gen_opt"], disc_for_gen, round_key)

    w = weights.astype(jnp.float32)
    wsum = jnp.maximum(w.sum(), 1e-12)
    metrics = {
        "disc_objective": jnp.sum(disc_objs * w) / wsum,
        "gen_objective": gen_obj,
        "participation": (w > 0).astype(jnp.float32).mean(),
    }
    new_state = {"gen": new_gen, "disc": disc_avg,
                 "gen_opt": new_gen_opt, "disc_opt": new_disc_opt}
    if "fault" in state:
        # advance the one-round-stale free-rider cache to this round's
        # broadcast payload (what a free-rider would have received and
        # can replay next round without computing)
        new_state["fault"] = {"stale": state["disc"]}
    return new_state, metrics


# ---------------------------------------------------------------------------
# Fused multi-round driver — R rounds per XLA dispatch
# ---------------------------------------------------------------------------

# PRNG salts for the per-round channel/scheduler randomness. The host
# loop's numpy stream is sequential; the fused path derives independent
# keys per round from the SAME root key the host loop folds for model
# math, so model randomness (and hence params) agrees round-for-round.
_SALT_RATES = 0x4A7E5
_SALT_SCHED = 0x5C4ED
_SALT_TIMING = 0x7133


def count_params(tree) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def schedule_and_time(pcfg: ProtocolConfig, channel, scheduler, sched_carry,
                      round_key, *, disc_nparams: int, gen_nparams: int,
                      disc_step_flops: float, gen_step_flops: float,
                      fedgan: bool, uplink_bits, faults=None):
    """Step 1 + channel accounting for one round, shared by EVERY
    execution layout of the fused engine (stacked `rounds_scan` and the
    mesh `shard_round.shard_rounds_scan`): the per-round rates/scheduler/
    timing keys are derived from `round_key` with fixed salts, so both
    layouts see bitwise-identical masks, stragglers, and weights.

    With a FaultConfig, per-round dropout (keyed off the SAME round_key,
    core/faults.py) knocks scheduled devices out of the mask before
    timing, and the program's per-device compute multipliers (stragglers
    slower, free-riders free) feed the wallclock model.

    Returns (mask, new_sched_carry, timing, weights).
    """
    k_rates = jax.random.fold_in(round_key, _SALT_RATES)
    k_sched = jax.random.fold_in(round_key, _SALT_SCHED)
    k_timing = jax.random.fold_in(round_key, _SALT_TIMING)

    # Schedule against a fresh fading draw, then time the round (second
    # draw, mirroring the host loop's two rng calls).
    rates = channel.uplink_rates(k_rates, scheduler.n_scheduled)
    mask, sched_carry = jax_scheduling.schedule_step(scheduler, sched_carry,
                                                     rates, k_sched)
    prog = faults_lib.fault_program(faults)
    compute_mult = None
    if prog is not None:
        mask = mask & ~prog.dropout_mask(round_key)
        compute_mult = prog.compute_mult
    timing = channel.round_timing(
        k_timing, mask, disc_params=disc_nparams, gen_params=gen_nparams,
        disc_step_flops=disc_step_flops, gen_step_flops=gen_step_flops,
        n_d=pcfg.n_d, n_g=pcfg.n_g, fedgan=fedgan, uplink_bits=uplink_bits,
        compute_mult=compute_mult)
    active = mask & ~timing.stragglers
    weights = jnp.where(active, float(pcfg.sample_size),
                        0.0).astype(jnp.float32)
    return mask, sched_carry, timing, weights


def uplink_payload_bits(state, pcfg: ProtocolConfig, *,
                        fedgan: bool = False) -> int:
    """Per-device upload payload in bits at the protocol's quantization
    width: phi only for the proposed framework, theta AND phi for FedGAN
    (the communication asymmetry Fig. 5 measures)."""
    bits = quantize.tree_bits(state["disc"], pcfg.quantize_bits)
    if fedgan:
        bits += quantize.tree_bits(state["gen"], pcfg.quantize_bits)
    return bits


def rounds_scan(round_fn, pcfg: ProtocolConfig, state, data_stacked, key,
                n_rounds: int, *, channel, scheduler, sched_carry=None,
                start_round=0, disc_step_flops: float = 1e9,
                gen_step_flops: float = 1e9, fedgan: bool = False,
                uplink_bits: Optional[int] = None,
                eval_fn: Optional[Callable] = None, eval_every: int = 0,
                faults=None):
    """The UNIFIED fused round engine: R communication rounds of ANY
    round function in one `lax.scan`.

    round_fn:  (state, data_stacked, weights, round_key) -> (state,
               metrics) — `gan_round` (via `gan_rounds_scan`) or
               `fedgan.fedgan_round` (via `fedgan.fedgan_rounds_scan`).
    channel:   core.jax_channel.JaxChannel (static placement, jittable)
    scheduler: core.jax_scheduling.JaxScheduler (policy static)
    sched_carry: scheduler carry from a previous chunk (None = fresh)
    start_round: absolute index of the first round; round t's model key
        is `fold_in(key, t)`, matching the host loop's per-round fold so
        chunked fused runs and the host oracle see identical streams.
    fedgan:    switches the channel's timing/wallclock composition to
        the FedGAN round shape (local G+D compute, both nets uploaded).
    uplink_bits: per-device upload payload in bits; None computes it
        from the state at `pcfg.quantize_bits` (`uplink_payload_bits`),
        so ablation bit widths shrink the simulated upload time too.
    eval_fn:   optional JITTABLE (gen_params, t) -> scalar, evaluated
        IN-SCAN via `lax.cond` on rounds where (t+1) % eval_every == 0;
        out["fid"] is the per-round series (NaN placeholder on skipped
        rounds) and out["fid_eval"] the boolean did-evaluate mask.

    Returns (state, sched_carry, out) where out stacks per-round
    {"metrics": {...: (R,)}, "wallclock_s": (R,), "mask": (R, K) bool,
    "weights": (R, K)[, "fid": (R,), "fid_eval": (R,)]}.
    """
    if sched_carry is None:
        sched_carry = scheduler.init_carry()
    disc_nparams = count_params(state["disc"])
    gen_nparams = count_params(state["gen"])
    if uplink_bits is None:
        uplink_bits = uplink_payload_bits(state, pcfg, fedgan=fedgan)

    def body(carry, t):
        st, sc = carry
        round_key = jax.random.fold_in(key, t)

        # Step 1 + channel accounting (layout-shared keying)
        mask, sc, timing, weights = schedule_and_time(
            pcfg, channel, scheduler, sc, round_key,
            disc_nparams=disc_nparams, gen_nparams=gen_nparams,
            disc_step_flops=disc_step_flops, gen_step_flops=gen_step_flops,
            fedgan=fedgan, uplink_bits=uplink_bits, faults=faults)

        # Steps 2-5
        st, metrics = round_fn(st, data_stacked, weights, round_key)
        wall = jax_channel.round_wallclock(timing, mask,
                                           schedule=pcfg.schedule,
                                           fedgan=fedgan)
        out = {"metrics": metrics, "wallclock_s": wall, "mask": mask,
               "weights": weights}
        if eval_fn is not None and eval_every > 0:
            # In-scan eval: lax.cond skips the branch on non-eval rounds
            # at runtime, so eval cost is paid only every eval_every
            # rounds while the chunk stays ONE compiled function. The
            # explicit eval mask (not a NaN sentinel) keeps a genuinely
            # NaN metric on an eval round distinguishable from "no eval".
            do_eval = (t + 1) % eval_every == 0
            out["fid"] = jax.lax.cond(
                do_eval,
                lambda g: jnp.float32(eval_fn(g, t)),
                lambda g: jnp.float32(jnp.nan), st["gen"])
            out["fid_eval"] = do_eval
        return (st, sc), out

    rounds = jnp.asarray(start_round) + jnp.arange(n_rounds)
    (state, sched_carry), out = jax.lax.scan(body, (state, sched_carry),
                                             rounds)
    return state, sched_carry, out


def gan_rounds_scan(spec: GanModelSpec, pcfg: ProtocolConfig, state,
                    data_stacked, key, n_rounds: int, *,
                    channel, scheduler, sched_carry=None, start_round=0,
                    disc_step_flops: float = 1e9,
                    gen_step_flops: float = 1e9,
                    uplink_bits: Optional[int] = None,
                    eval_fn: Optional[Callable] = None,
                    eval_every: int = 0, faults=None, reducer=None):
    """R fused rounds of the PROPOSED protocol (see `rounds_scan`)."""
    round_fn = lambda st, d, w, k: gan_round(spec, pcfg, st, d, w, k,
                                             faults=faults, reducer=reducer)
    return rounds_scan(round_fn, pcfg, state, data_stacked, key, n_rounds,
                       channel=channel, scheduler=scheduler,
                       sched_carry=sched_carry, start_round=start_round,
                       disc_step_flops=disc_step_flops,
                       gen_step_flops=gen_step_flops, fedgan=False,
                       uplink_bits=uplink_bits, eval_fn=eval_fn,
                       eval_every=eval_every, faults=faults)


def centralized_step(spec: GanModelSpec, pcfg: ProtocolConfig, state, data,
                     round_key):
    """Centralized baseline (Fig. 4): one worker, same budget — n_d
    discriminator steps on the pooled data then n_g generator steps."""
    disc, disc_opt, disc_obj = device_update(
        spec, pcfg, state["gen"], state["disc"],
        jax.tree.map(lambda x: x[0], state["disc_opt"]), data, round_key,
        jnp.int32(0))
    gen, gen_opt, gen_obj = server_update(
        spec, pcfg, state["gen"], state["gen_opt"], disc, round_key)
    new_state = {"gen": gen, "disc": disc, "gen_opt": gen_opt,
                 "disc_opt": jax.tree.map(lambda x: x[None], disc_opt)}
    return new_state, {"disc_objective": disc_obj, "gen_objective": gen_obj,
                       "participation": jnp.float32(1.0)}

"""Uplink quantization (paper Section IV: 16 bits per parameter).

Uniform stochastic quantization with a per-tensor scale. With the
default 16 bits the quantization error is negligible (matching the
paper's implicit assumption); lower bit widths are exposed for
communication-efficiency ablations.

Since PR 2 the uplink is quantized INSIDE the round math
(`protocol.gan_round` Step 3, `fedgan.fedgan_round`), so both drivers
— the per-round host oracle and the fused `lax.scan` engine — and the
shard_map path apply bitwise-identical quantization: device k's
round-t draw is keyed by fold_in(fold_in(round_key, _SALT_QUANT), k),
independent of how the device axis is executed (vmap, scan, or a mesh
slice). `tree_bits` also feeds the channel's uplink payload-size
timing, so ablation bit widths shrink simulated upload time too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Salt separating the quantization stream from the shared-noise /
# data-sampling streams of core.protocol.
_SALT_QUANT = 0x0b175


def quantize_tree(key, tree, bits: int = 16):
    """Returns (quantized_int_tree, scales_tree).

    The stochastic-rounding randomness is ONE uniform draw over the
    whole flattened payload, sliced per leaf — an order of magnitude
    fewer threefry dispatches than per-leaf keys at typical leaf
    counts, which matters inside the fused driver's per-round scan.
    """
    levels = 2 ** (bits - 1) - 1
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [int(x.size) for x in leaves]
    rnd_flat = jax.random.uniform(key, (sum(sizes),))

    q_leaves, scales = [], []
    off = 0
    for x, size in zip(leaves, sizes):
        rnd = rnd_flat[off:off + size].reshape(x.shape)
        off += size
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / levels
        scaled = x / scale
        low = jnp.floor(scaled)
        p_up = scaled - low
        q = low + (rnd < p_up)
        q_leaves.append(jnp.clip(q, -levels - 1, levels).astype(jnp.int32))
        scales.append(scale)
    return (jax.tree_util.tree_unflatten(treedef, q_leaves),
            jax.tree_util.tree_unflatten(treedef, scales))


def dequantize_tree(q_tree, scales_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scales_tree)


def roundtrip(key, tree, bits: int = 16):
    """Quantize-dequantize (what the server receives on the uplink)."""
    if bits >= 32:
        return tree
    q, s = quantize_tree(key, tree, bits)
    deq = dequantize_tree(q, s)
    return jax.tree.map(lambda d, x: d.astype(x.dtype), deq, tree)


def device_uplink_key(round_key, dev_index):
    """Key for device `dev_index`'s uplink quantization this round.

    One definition shared by every execution layout of the device axis
    (vmap in `gan_round`, per-slice in `shard_round`), so they quantize
    bitwise-identically.
    """
    return jax.random.fold_in(jax.random.fold_in(round_key, _SALT_QUANT),
                              dev_index)


def roundtrip_stacked(round_key, stacked_tree, bits: int = 16):
    """Per-device quantize-dequantize of a pytree with leading axis K
    (Step 3: every scheduled device quantizes its OWN upload with its
    own stream)."""
    if bits >= 32:
        return stacked_tree
    n_devices = jax.tree_util.tree_leaves(stacked_tree)[0].shape[0]
    keys = jax.vmap(lambda i: device_uplink_key(round_key, i))(
        jnp.arange(n_devices))
    return jax.vmap(lambda k, t: roundtrip(k, t, bits))(keys, stacked_tree)


def tree_bits(tree, bits: int = 16) -> int:
    """Total uplink payload in bits for a parameter pytree."""
    return bits * sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))

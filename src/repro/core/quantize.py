"""Uplink quantization (paper Section IV: 16 bits per parameter).

Uniform stochastic quantization with a per-tensor scale. With the
default 16 bits the quantization error is negligible (matching the
paper's implicit assumption); lower bit widths are exposed for
communication-efficiency ablations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_tree(key, tree, bits: int = 16):
    """Returns (quantized_int_tree, scales_tree)."""
    levels = 2 ** (bits - 1) - 1
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))

    q_leaves, scales = [], []
    for k, x in zip(keys, leaves):
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / levels
        scaled = x / scale
        low = jnp.floor(scaled)
        p_up = scaled - low
        rnd = jax.random.uniform(k, x.shape)
        q = low + (rnd < p_up)
        q_leaves.append(jnp.clip(q, -levels - 1, levels).astype(jnp.int32))
        scales.append(scale)
    return (jax.tree_util.tree_unflatten(treedef, q_leaves),
            jax.tree_util.tree_unflatten(treedef, scales))


def dequantize_tree(q_tree, scales_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scales_tree)


def roundtrip(key, tree, bits: int = 16):
    """Quantize-dequantize (what the server receives on the uplink)."""
    if bits >= 32:
        return tree
    q, s = quantize_tree(key, tree, bits)
    deq = dequantize_tree(q, s)
    return jax.tree.map(lambda d, x: d.astype(x.dtype), deq, tree)


def tree_bits(tree, bits: int = 16) -> int:
    """Total uplink payload in bits for a parameter pytree."""
    return bits * sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))

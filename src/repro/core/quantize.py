"""Uplink quantization (paper Section IV: 16 bits per parameter).

Uniform stochastic quantization with a per-tensor scale. With the
default 16 bits the quantization error is negligible (matching the
paper's implicit assumption); lower bit widths are exposed for
communication-efficiency ablations.

Since PR 2 the uplink is quantized INSIDE the round math
(`protocol.gan_round` Step 3, `fedgan.fedgan_round`), so both drivers
— the per-round host oracle and the fused `lax.scan` engine — and the
shard_map path apply bitwise-identical quantization: device k's
round-t draw is keyed by fold_in(fold_in(round_key, _SALT_QUANT), k),
independent of how the device axis is executed (vmap, scan, or a mesh
slice). `tree_bits` also feeds the channel's uplink payload-size
timing, so ablation bit widths shrink simulated upload time too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Salt separating the quantization stream from the shared-noise /
# data-sampling streams of core.protocol.
_SALT_QUANT = 0x0b175


def _quantize_leaf(x, rnd, amax, levels):
    """One leaf's uniform stochastic quantization: (q_int32, scale).
    The ONE definition of the scale floor / rounding / clip math —
    `quantize_tree` and `roundtrip_tp` both call it, so the tp-bitwise
    contract (TP width never changes the quantizer) cannot drift.

    All math runs in float32 regardless of the leaf dtype: under bf16
    type promotion the clip bound `levels` = 32767 is not representable
    (it rounds to 32768), so a bf16-domain clip can emit q outside its
    own [-levels-1, levels] contract — overflowing the int16 wire the
    ring collective (kernels/ring_wavg) puts the payload on."""
    scale = jnp.maximum(amax.astype(jnp.float32), 1e-12) / levels
    scaled = x.astype(jnp.float32) / scale
    low = jnp.floor(scaled)
    q = low + (rnd < scaled - low)
    return jnp.clip(q, -levels - 1, levels).astype(jnp.int32), scale


def quantize_tree(key, tree, bits: int = 16):
    """Returns (quantized_int_tree, scales_tree).

    The stochastic-rounding randomness is ONE uniform draw over the
    whole flattened payload, sliced per leaf — an order of magnitude
    fewer threefry dispatches than per-leaf keys at typical leaf
    counts, which matters inside the fused driver's per-round scan.
    """
    levels = 2 ** (bits - 1) - 1
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    sizes = [int(x.size) for x in leaves]
    rnd_flat = jax.random.uniform(key, (sum(sizes),))

    q_leaves, scales = [], []
    off = 0
    for x, size in zip(leaves, sizes):
        rnd = rnd_flat[off:off + size].reshape(x.shape)
        off += size
        q, scale = _quantize_leaf(x, rnd, jnp.max(jnp.abs(x)), levels)
        q_leaves.append(q)
        scales.append(scale)
    return (jax.tree_util.tree_unflatten(treedef, q_leaves),
            jax.tree_util.tree_unflatten(treedef, scales))


def dequantize_tree(q_tree, scales_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s,
                        q_tree, scales_tree)


def roundtrip(key, tree, bits: int = 16):
    """Quantize-dequantize (what the server receives on the uplink)."""
    if bits >= 32:
        return tree
    q, s = quantize_tree(key, tree, bits)
    deq = dequantize_tree(q, s)
    return jax.tree.map(lambda d, x: d.astype(x.dtype), deq, tree)


def roundtrip_tp(key, tree, bits: int = 16, *, tp_axis=None, tp: int = 1,
                 shard_dims=None):
    """`roundtrip` for a TENSOR-PARALLEL shard of the upload payload.

    Inside a (device x model) mesh slice each TP rank holds only its
    Megatron shard of `tree`, but the paper's worker quantizes the WHOLE
    model with one stream. This reconstructs exactly that: the
    stochastic-rounding uniforms are drawn over the GLOBAL flattened
    payload (same key, same draw order as `roundtrip` at tp=1) and each
    rank slices its shard's positions; the per-tensor scale comes from
    the GLOBAL abs-max via `lax.pmax` over the model axis. A tp=2 run
    therefore quantizes bitwise-identically to tp=1 given the same
    values — TP changes the arithmetic only through matmul reduction
    order, never through the quantizer.

    shard_dims: per-leaf shard dim (negative) or None, as a tuple
    aligned with `tree_flatten(tree)` order — produced by
    `sharding.rules.tp_tree_dims` on the GLOBAL payload tree. Leaves
    with None replicate: every rank quantizes the full leaf with the
    same slice of the stream, staying replicated.

    KNOWN LIMITATION: reconstructing the worker-global stream means
    each rank materializes O(global payload) uniforms (rnd_flat + one
    global-shaped buffer per leaf) transiently during Step 3 — the
    quantizer's peak memory does NOT shrink with tp, only the persistent
    state and the Algorithm-2 all-gather do. That is the price of the
    tp-bitwise contract (tp must never change the quantizer); a
    counter-level sliced stream that keeps the contract without the
    global buffer is a ROADMAP item.
    """
    if bits >= 32:
        return tree
    if tp_axis is None or tp <= 1:
        return roundtrip(key, tree, bits)
    levels = 2 ** (bits - 1) - 1
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    assert shard_dims is not None and len(shard_dims) == len(leaves)
    rank = jax.lax.axis_index(tp_axis)

    # Global shapes/sizes: the sharded dim is tp x its local extent.
    gshapes = []
    for x, d in zip(leaves, shard_dims):
        shape = list(x.shape)
        if d is not None:
            shape[d] = shape[d] * tp
        gshapes.append(tuple(shape))
    gsizes = [1 for _ in gshapes]
    for i, shape in enumerate(gshapes):
        for s in shape:
            gsizes[i] *= s
    rnd_flat = jax.random.uniform(key, (sum(gsizes),))

    out, off = [], 0
    for x, d, gshape, gsize in zip(leaves, shard_dims, gshapes, gsizes):
        rnd = rnd_flat[off:off + gsize].reshape(gshape)
        off += gsize
        amax = jnp.max(jnp.abs(x))
        if d is not None:
            start = [0] * x.ndim
            start[d % x.ndim] = rank * x.shape[d]
            rnd = jax.lax.dynamic_slice(rnd, start, x.shape)
            amax = jax.lax.pmax(amax, tp_axis)
        q, scale = _quantize_leaf(x, rnd, amax, levels)
        out.append((q.astype(jnp.float32) * scale).astype(x.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def device_uplink_key(round_key, dev_index):
    """Key for device `dev_index`'s uplink quantization this round.

    One definition shared by every execution layout of the device axis
    (vmap in `gan_round`, per-slice in `shard_round`), so they quantize
    bitwise-identically.
    """
    return jax.random.fold_in(jax.random.fold_in(round_key, _SALT_QUANT),
                              dev_index)


def roundtrip_stacked(round_key, stacked_tree, bits: int = 16):
    """Per-device quantize-dequantize of a pytree with leading axis K
    (Step 3: every scheduled device quantizes its OWN upload with its
    own stream)."""
    if bits >= 32:
        return stacked_tree
    n_devices = jax.tree_util.tree_leaves(stacked_tree)[0].shape[0]
    keys = jax.vmap(lambda i: device_uplink_key(round_key, i))(
        jnp.arange(n_devices))
    return jax.vmap(lambda k, t: roundtrip(k, t, bits))(keys, stacked_tree)


def tree_bits(tree, bits: int = 16) -> int:
    """Total uplink payload in bits for a parameter pytree."""
    return bits * sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))

"""Algorithm 2 — server discriminator averaging.

    phi = (sum_{k in S} m_k phi_k) / (sum_{k in S} m_k)

Scheduling is expressed through the weight vector: w_k = m_k for
scheduled devices and 0 otherwise, so one weighted mean covers partial
participation, stragglers, and unequal sample sizes.

Four interchangeable implementations:
  * `weighted_average`      — stacked leading device axis (pjit/GSPMD path;
                              the mean over the stacked axis lowers to the
                              all-reduce when that axis is mesh-sharded)
  * `weighted_average_psum` — explicit collective for the shard_map
    (mesh-layout) path: per-leaf weighted psum with ``impl="jnp"``, or
    the mesh hot path with ``impl="pallas"`` — the local tree flattened
    into one payload, all-gathered once, and reduced by the Pallas
    `wavg` kernel (the default inside `shard_round.shard_rounds_scan`)
  * the Pallas `wavg` kernel (repro.kernels.wavg) — the MXU reduction
    both ``impl="pallas"`` paths call into (interpret mode on CPU)
  * ``impl="ring"`` (repro.kernels.ring_wavg) — chunked double-buffered
    `lax.ppermute` ring with dequantize-and-accumulate fused into the
    Pallas kernel: the quantized uplink payload stays ENCODED on the
    wire (int16 at 16 bits) and per-rank wire bytes drop from the flat
    path's K*N*4 to ~(K-1)*N*2 — the large-K scaling path. Single
    device axis, tp=1, no robust reducers (those stay flat). Pass
    ``quantize_key``/``quantize_bits`` to keep the wire encoded.

NO-SURVIVOR SEMANTICS: a round where every weight is zero (all workers
dropped) has no defined average — `_normalized`'s `max(total, 1e-12)`
guard would otherwise multiply the global by ~0. Every impl (host
stacked, jnp, pallas, robust, ring) accepts ``fallback``: a pytree
shaped like the result that is returned unchanged when the total weight
is zero, so callers keep the previous global parameters
(tests/test_no_survivor.py pins this under FaultConfig(dropout=1.0)).

ROBUST REDUCERS: ``impl`` may also name a robust aggregation method
from `repro.kernels.robust_avg` (`ROBUST_METHODS`: "trimmed_mean",
"norm_clip", "krum") with a `RobustConfig` supplying its parameters.
They ride the SAME flatten -> one all-gather -> one Pallas kernel hot
path as ``impl="pallas"`` but reduce with participation-mask-aware RAW
weights (0 = dropped worker contributes nothing, payload shape
unchanged) — the counter-measure to hostile uploads (core/faults.py).
In their identity regimes (trim=0 / clip_factor large / krum_f=0) they
reproduce the plain wavg weights bitwise.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.robust_avg.ops import ROBUST_METHODS, RobustConfig


def _normalized(weights):
    weights = weights.astype(jnp.float32)
    total = jnp.sum(weights)
    return weights / jnp.maximum(total, 1e-12)


def _flatten_stacked(stacked_params):
    """Flatten a stacked pytree (leading axis K on every leaf) into one
    (K, N) f32 matrix — the SAME leaf order and per-leaf ravel as the
    psum path's per-slice concat, so stacked and mesh robust reductions
    see identical payload columns."""
    leaves, treedef = jax.tree_util.tree_flatten(stacked_params)
    k = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.reshape(k, -1).astype(jnp.float32) for x in leaves], axis=1)
    return flat, leaves, treedef


def _unflatten_row(avg_flat, leaves, treedef):
    out, off = [], 0
    for x in leaves:
        size = x.size // x.shape[0]
        out.append(avg_flat[off:off + size].reshape(x.shape[1:])
                   .astype(x.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)


def _apply_fallback(avg, fallback, total):
    """Keep `fallback` (the previous global) when no worker survived."""
    if fallback is None:
        return avg
    return jax.tree.map(
        lambda a, f: jnp.where(total > 0, a, f.astype(a.dtype)),
        avg, fallback)


def weighted_average(stacked_params, weights, *, impl: str = "jnp",
                     robust: Optional[RobustConfig] = None,
                     interpret=None, fallback=None):
    """stacked_params: pytree with leading device axis K; weights: (K,).

    Returns the weighted average with the leading axis contracted.
    `robust` selects a robust reducer (repro.kernels.robust_avg) run on
    the flattened (K, N) payload with the RAW weights — one Pallas call
    for the whole tree, matching the mesh hot path column-for-column.
    `fallback` (unstacked, result-shaped) is returned when the total
    weight is zero — the no-survivor round keeps the previous global.
    """
    if robust is not None:
        from repro.kernels.robust_avg import ops as robust_ops

        flat, leaves, treedef = _flatten_stacked(stacked_params)
        if not leaves:
            return stacked_params
        avg_flat = robust_ops.robust_average(
            flat, weights.astype(jnp.float32), robust, interpret=interpret)
        avg = _unflatten_row(avg_flat, leaves, treedef)
        return _apply_fallback(avg, fallback,
                               jnp.sum(weights.astype(jnp.float32)))

    w = _normalized(weights)

    if impl == "pallas":
        from repro.kernels.wavg import ops as wavg_ops

        def avg_leaf(x):
            return wavg_ops.weighted_average(x, w).astype(x.dtype)
    else:
        def avg_leaf(x):
            wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
            return jnp.sum(x.astype(jnp.float32) * wx, axis=0).astype(x.dtype)

    avg = jax.tree.map(avg_leaf, stacked_params)
    return _apply_fallback(avg, fallback,
                           jnp.sum(weights.astype(jnp.float32)))


def weighted_average_psum(local_params, local_weight, *, axis_names,
                          impl: str = "jnp", robust: Optional[RobustConfig] = None,
                          interpret=None, fallback=None,
                          quantize_key=None, quantize_bits: int = 32):
    """shard_map path: every mesh slice holds ITS device's parameters;
    Algorithm 2 is a weighted reduction over the device axes.

    `axis_names` may be a SUBSET of the live mesh axes: on the 2-D
    (device x model) mesh the reduction runs over the device axes only,
    so each tensor-parallel rank averages just its parameter shard —
    the all-gather payload shrinks by the TP factor and the result
    stays sharded over the model axis
    (tests/test_averaging_property.py::TestAxisSubsetAveraging).

    impl="jnp"    — per-leaf weighted psum (one collective per leaf).
    impl="pallas" — the mesh hot path: the local tree is flattened into
        ONE contiguous f32 payload, all-gathered over the device axes
        into a (K, N) matrix, and reduced by the Pallas `wavg` kernel
        ((1, K) x (K, N) on the MXU) — one collective + one kernel per
        round instead of a tree of jnp means. `interpret=None` lets the
        kernel wrapper pick interpret mode on CPU, so the same code path
        runs everywhere (tests force it through interpret on host).

    A non-None `robust` routes the SAME flat-gather path through the
    selected robust reducer with the RAW gathered weights (0 = dropped
    worker contributes nothing) — still exactly one payload all-gather
    + one Pallas kernel call per round.

    impl="ring"  — the ring collective (repro.kernels.ring_wavg): k-1
        chunked `lax.ppermute` hops with dequantize-and-accumulate
        fused into the Pallas kernel. With `quantize_key` and
        `quantize_bits` < 32 the payload travels ENCODED (int16 at 16
        bits) using the same `quantize_tree` stream as the flat path's
        uplink roundtrip. Single device axis only; does not compose
        with `robust`.

    `fallback` (local-params-shaped) is returned when the gathered
    total weight is zero — every impl keeps the previous global on a
    no-survivor round instead of multiplying it by ~0.
    """
    if impl == "ring":
        if robust is not None:
            raise ValueError(
                "impl='ring' does not compose with robust reducers; "
                "robust aggregation stays on the flat gather path")
        from repro.kernels.ring_wavg import ops as ring_ops

        return ring_ops.ring_average_psum(
            local_params, local_weight, axis_names=axis_names,
            quantize_key=quantize_key, bits=quantize_bits,
            interpret=interpret, fallback=fallback)

    if impl == "pallas" or robust is not None:
        from repro.kernels.wavg import ops as wavg_ops

        leaves, treedef = jax.tree_util.tree_flatten(local_params)
        if not leaves:
            return local_params
        flat = jnp.concatenate(
            [jnp.ravel(x).astype(jnp.float32) for x in leaves])
        stacked = jax.lax.all_gather(flat, axis_names)       # (K, N)
        w_full = jax.lax.all_gather(
            local_weight.astype(jnp.float32), axis_names)    # (K,)
        if robust is not None:
            from repro.kernels.robust_avg import ops as robust_ops

            avg_flat = robust_ops.robust_average(stacked, w_full, robust,
                                                 interpret=interpret)
        else:
            w_norm = _normalized(w_full)
            avg_flat = wavg_ops.weighted_average(stacked, w_norm,
                                                 interpret=interpret)
        out, off = [], 0
        for x in leaves:
            out.append(avg_flat[off:off + x.size].reshape(x.shape)
                       .astype(x.dtype))
            off += x.size
        avg = jax.tree_util.tree_unflatten(treedef, out)
        return _apply_fallback(avg, fallback, jnp.sum(w_full))

    if impl != "jnp":
        raise ValueError(f"unknown weighted_average_psum impl {impl!r}")

    total = jax.lax.psum(local_weight.astype(jnp.float32), axis_names)

    def avg_leaf(x):
        contrib = x.astype(jnp.float32) * local_weight.astype(jnp.float32)
        summed = jax.lax.psum(contrib, axis_names)
        return (summed / jnp.maximum(total, 1e-12)).astype(x.dtype)

    avg = jax.tree.map(avg_leaf, local_params)
    return _apply_fallback(avg, fallback, total)


def broadcast_like(params, n: int):
    """Tile a pytree to a stacked leading device axis (Step 5 broadcast)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)


def select_tree(mask_scalar, tree_true, tree_false):
    """Per-device jnp.where over pytrees (straggler exclusion)."""
    return jax.tree.map(
        lambda a, b: jnp.where(mask_scalar.reshape((-1,) + (1,) * (a.ndim - 1)),
                               a, b),
        tree_true, tree_false)

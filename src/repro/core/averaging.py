"""Algorithm 2 — server discriminator averaging.

    phi = (sum_{k in S} m_k phi_k) / (sum_{k in S} m_k)

Scheduling is expressed through the weight vector: w_k = m_k for
scheduled devices and 0 otherwise, so one weighted mean covers partial
participation, stragglers, and unequal sample sizes.

Three interchangeable implementations:
  * `weighted_average`      — stacked leading device axis (pjit/GSPMD path;
                              the mean over the stacked axis lowers to the
                              all-reduce when that axis is mesh-sharded)
  * `weighted_average_psum` — explicit collective for the shard_map
    (mesh-layout) path: per-leaf weighted psum with ``impl="jnp"``, or
    the mesh hot path with ``impl="pallas"`` — the local tree flattened
    into one payload, all-gathered once, and reduced by the Pallas
    `wavg` kernel (the default inside `shard_round.shard_rounds_scan`)
  * the Pallas `wavg` kernel (repro.kernels.wavg) — the MXU reduction
    both ``impl="pallas"`` paths call into (interpret mode on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _normalized(weights):
    weights = weights.astype(jnp.float32)
    total = jnp.sum(weights)
    return weights / jnp.maximum(total, 1e-12)


def weighted_average(stacked_params, weights, *, impl: str = "jnp"):
    """stacked_params: pytree with leading device axis K; weights: (K,).

    Returns the weighted average with the leading axis contracted.
    """
    w = _normalized(weights)

    if impl == "pallas":
        from repro.kernels.wavg import ops as wavg_ops

        def avg_leaf(x):
            return wavg_ops.weighted_average(x, w).astype(x.dtype)
    else:
        def avg_leaf(x):
            wx = w.reshape((-1,) + (1,) * (x.ndim - 1)).astype(jnp.float32)
            return jnp.sum(x.astype(jnp.float32) * wx, axis=0).astype(x.dtype)

    return jax.tree.map(avg_leaf, stacked_params)


def weighted_average_psum(local_params, local_weight, *, axis_names,
                          impl: str = "jnp", interpret=None):
    """shard_map path: every mesh slice holds ITS device's parameters;
    Algorithm 2 is a weighted reduction over the device axes.

    `axis_names` may be a SUBSET of the live mesh axes: on the 2-D
    (device x model) mesh the reduction runs over the device axes only,
    so each tensor-parallel rank averages just its parameter shard —
    the all-gather payload shrinks by the TP factor and the result
    stays sharded over the model axis
    (tests/test_averaging_property.py::TestAxisSubsetAveraging).

    impl="jnp"    — per-leaf weighted psum (one collective per leaf).
    impl="pallas" — the mesh hot path: the local tree is flattened into
        ONE contiguous f32 payload, all-gathered over the device axes
        into a (K, N) matrix, and reduced by the Pallas `wavg` kernel
        ((1, K) x (K, N) on the MXU) — one collective + one kernel per
        round instead of a tree of jnp means. `interpret=None` lets the
        kernel wrapper pick interpret mode on CPU, so the same code path
        runs everywhere (tests force it through interpret on host).
    """
    if impl == "pallas":
        from repro.kernels.wavg import ops as wavg_ops

        leaves, treedef = jax.tree_util.tree_flatten(local_params)
        if not leaves:
            return local_params
        flat = jnp.concatenate(
            [jnp.ravel(x).astype(jnp.float32) for x in leaves])
        stacked = jax.lax.all_gather(flat, axis_names)       # (K, N)
        w_full = jax.lax.all_gather(
            local_weight.astype(jnp.float32), axis_names)    # (K,)
        w_norm = _normalized(w_full)
        avg_flat = wavg_ops.weighted_average(stacked, w_norm,
                                             interpret=interpret)
        out, off = [], 0
        for x in leaves:
            out.append(avg_flat[off:off + x.size].reshape(x.shape)
                       .astype(x.dtype))
            off += x.size
        return jax.tree_util.tree_unflatten(treedef, out)

    if impl != "jnp":
        raise ValueError(f"unknown weighted_average_psum impl {impl!r}")

    total = jax.lax.psum(local_weight.astype(jnp.float32), axis_names)

    def avg_leaf(x):
        contrib = x.astype(jnp.float32) * local_weight.astype(jnp.float32)
        summed = jax.lax.psum(contrib, axis_names)
        return (summed / jnp.maximum(total, 1e-12)).astype(x.dtype)

    return jax.tree.map(avg_leaf, local_params)


def broadcast_like(params, n: int):
    """Tile a pytree to a stacked leading device axis (Step 5 broadcast)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), params)


def select_tree(mask_scalar, tree_true, tree_false):
    """Per-device jnp.where over pytrees (straggler exclusion)."""
    return jax.tree.map(
        lambda a, b: jnp.where(mask_scalar.reshape((-1,) + (1,) * (a.ndim - 1)),
                               a, b),
        tree_true, tree_false)

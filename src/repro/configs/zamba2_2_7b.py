"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

Assigned: 54L, d_model=2560, 32H (GQA kv=32), d_ff=10240, vocab=32000,
ssm_state=64. The single shared attention+MLP block (one parameter set)
is invoked after every 6 Mamba2 blocks (9 invocations over 54 layers).
"""
from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,            # Mamba2 blocks
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,          # MHA on the shared block (assigned kv=32)
        d_ff=10240,             # shared block's MLP
        vocab=32000,
        attn_every=6,
        ssm=SSMConfig(d_state=64, head_dim=64, expand=2, n_groups=1),
        source="arXiv:2411.15242 (Zamba2)",
    )

"""granite-moe-3b-a800m [moe] — fine-grained MoE, top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

Assigned: 32L, d_model=1536, 24H (GQA kv=8), d_ff=512 (per expert),
vocab=49155, MoE 40 experts top-8. (The assignment's config line says
40e top-8; we follow the explicit config line.)
"""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab=49155,
        moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                      group_size=1024),
        source="hf:ibm-granite/granite-3.0-3b-a800m-base",
    )

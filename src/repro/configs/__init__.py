"""Architecture registry: ``get_arch_config(name)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import (
    ArchConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    ProtocolConfig,
    MeshConfig,
    INPUT_SHAPES,
)

ARCH_IDS = [
    "mamba2_130m",
    "mixtral_8x22b",
    "whisper_base",
    "granite_3_2b",
    "qwen3_1_7b",
    "granite_moe_3b_a800m",
    "zamba2_2_7b",
    "gemma3_12b",
    "minitron_4b",
    "llama_3_2_vision_90b",
]

# Canonical (dashed) ids as assigned, mapped to module names.
CANONICAL = {
    "mamba2-130m": "mamba2_130m",
    "mixtral-8x22b": "mixtral_8x22b",
    "whisper-base": "whisper_base",
    "granite-3-2b": "granite_3_2b",
    "qwen3-1.7b": "qwen3_1_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "zamba2-2.7b": "zamba2_2_7b",
    "gemma3-12b": "gemma3_12b",
    "minitron-4b": "minitron_4b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
}


def get_arch_config(name: str) -> ArchConfig:
    mod_name = CANONICAL.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS and mod_name != "dcgan":
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(CANONICAL)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


def list_archs():
    return list(CANONICAL.keys())

"""Architecture / shape / protocol configuration schema.

Every assigned architecture gets a module in `repro/configs/<id>.py`
exposing `config() -> ArchConfig` with the exact assigned geometry and a
source citation. `ArchConfig.reduced()` yields the CPU smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int            # per-expert hidden size
    capacity_factor: float = 1.25
    group_size: int = 2048
    dispatch: str = "einsum"    # "einsum" (GShard baseline) | "sort" (lean)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    rope_base: float = 10000.0
    # Sliding-window attention: window for ALL attention layers...
    window: Optional[int] = None
    # ...or a local:global pattern (n_local, n_global, local_window).
    local_global: Optional[Tuple[int, int, int]] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): number of SSM blocks between shared-attention calls.
    attn_every: Optional[int] = None
    # encdec (whisper): encoder depth (n_layers counts DECODER layers).
    n_enc_layers: int = 0
    enc_seq: int = 1500          # whisper: 30 s audio -> 1500 frames
    # vlm (llama-3.2-vision): a cross-attn layer after every N self layers.
    cross_attn_every: Optional[int] = None
    n_image_tokens: int = 1600
    # GAN heads
    d_z: int = 128               # generator noise channel dim
    # Discriminator depth (None -> same as generator). The paper's devices
    # hold whole discriminators; for the >=40B backbones a full-depth local
    # replica cannot fit one device-group's HBM, so the local discriminator
    # is a shallower stack of the same family (DESIGN.md §Changed-assumptions).
    disc_layers: Optional[int] = None
    norm_eps: float = 1e-6
    use_attn_bias: bool = False  # whisper uses biases
    # flash path lays kv-heads on the TP axis by repeating k/v to full
    # heads (useful when n_kv_heads doesn't divide the model axis)
    flash_repeat_kv: bool = False
    # fused qkv / in+gate projections: one matmul + ONE dx all-reduce in
    # the TP backward instead of 3 (qkv) / 2 (in,gate) — §Perf lever
    fuse_proj: bool = False
    tie_embeddings: bool = False
    source: str = ""             # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    # ----- layer grouping for scan-over-layers ---------------------------
    @property
    def group_pattern(self) -> Tuple[str, ...]:
        """Sublayer kinds of one repeated group."""
        if self.family == "ssm":
            return ("ssm",)
        if self.family == "hybrid":
            assert self.attn_every
            return ("ssm",) * self.attn_every + ("shared_attn",)
        if self.family == "vlm":
            assert self.cross_attn_every
            return ("attn",) * self.cross_attn_every + ("cross",)
        if self.family == "encdec":
            return ("attn", "cross")   # each decoder layer self+cross attends
        if self.local_global is not None:
            n_local, n_global, _ = self.local_global
            return ("attn_local",) * n_local + ("attn_global",) * n_global
        return ("attn",)

    @property
    def n_groups_stack(self) -> int:
        pat = self.group_pattern
        per_group = sum(1 for kind in pat if kind != "cross")
        if self.family == "vlm":
            # n_layers counts self+cross layers together (100 = 80 self + 20 cross)
            per_group = len(pat)
        if self.family == "hybrid":
            # n_layers counts SSM blocks; shared attention is extra
            per_group = self.attn_every
        assert self.n_layers % per_group == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by group of {per_group}"
        return self.n_layers // per_group

    def sublayer_window(self, kind: str) -> Optional[int]:
        if kind == "attn_local":
            return self.local_global[2]
        if kind == "attn_global":
            return None
        return self.window

    def reduced(self) -> "ArchConfig":
        """CPU smoke-test variant of the same family."""
        pat_len = len(self.group_pattern)
        if self.family == "vlm":
            layers = pat_len          # one group
        elif self.family == "hybrid":
            layers = self.attn_every  # one group (+1 shared attn)
        elif self.local_global is not None:
            layers = pat_len          # one local:global group
        else:
            layers = 2
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_heads = max(2, min(self.n_heads, d_model // head_dim))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        changes = dict(
            n_layers=layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=n_kv, head_dim=head_dim,
            d_ff=min(self.d_ff, 512), vocab=min(self.vocab, 512),
            d_z=32, n_enc_layers=min(self.n_enc_layers, 2), enc_seq=16,
            n_image_tokens=8,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128), group_size=64)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=min(self.ssm.d_state, 16),
                head_dim=32, chunk=16)
        if self.local_global is not None:
            changes["local_global"] = (self.local_global[0],
                                       self.local_global[1], 8)
        if self.window is not None:
            changes["window"] = min(self.window, 8)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ProtocolConfig:
    """The paper's training-protocol knobs (Section III, Section IV)."""
    n_devices: int = 10          # K
    n_d: int = 5                 # local discriminator steps (Algorithm 1)
    n_g: int = 5                 # server generator steps (Algorithm 3)
    sample_size: int = 128       # m_k
    server_sample_size: int = 128  # M
    lr_d: float = 2e-4           # eta_d
    lr_g: float = 2e-4           # eta_g
    schedule: str = "serial"     # "serial" | "parallel"
    # Gradient-accumulation microbatch sizes (None = whole sample batch in
    # one fwd/bwd). Caps remat-carry activation memory at depth x micro.
    micro_batch_d: Optional[int] = None
    micro_batch_g: Optional[int] = None
    # Beyond-paper optimization (exact same math): the shared-seed design
    # makes every device's fake batch IDENTICAL, so the generator forward
    # can run once per local step (sharded over the device axes) instead
    # of replicated K times inside each device's update. See §Perf.
    hoist_fakes: bool = False
    scheduler: str = "all"       # "all" | "round_robin" | "best_channel" | "prop_fair"
    scheduling_ratio: float = 1.0
    quantize_bits: int = 16      # uplink quantization (paper: 16 bit)
    optimizer: str = "sgd"       # paper uses plain mini-batch SGD


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    fsdp: bool = False           # shard generator params over the data axis

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

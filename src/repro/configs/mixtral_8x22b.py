"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

Assigned: 56L, d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=32768,
MoE 8 experts top-2, sliding-window attention.
"""
from repro.configs.base import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=32768,
        window=4096,            # SWA per assignment [arXiv:2310.06825 recipe]
        rope_base=1_000_000.0,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
        disc_layers=8,          # local-replica HBM budget (DESIGN.md)
        source="arXiv:2401.04088 (Mixtral of Experts)",
    )

"""whisper-base [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

Assigned: 6L, d_model=512, 8H (GQA kv=8), d_ff=2048, vocab=51865.
The mel-spectrogram + conv feature extractor is STUBBED per instructions:
`input_specs()` feeds precomputed frame embeddings (b, enc_seq, d_model).
Positions use RoPE instead of Whisper's learned/sinusoidal absolute
embeddings so the assigned 32k serving shapes are representable
(adaptation recorded in DESIGN.md).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,              # decoder layers
        n_enc_layers=6,
        enc_seq=1500,            # 30 s of audio at 50 Hz after the conv stub
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51865,
        use_attn_bias=True,
        source="arXiv:2212.04356 (Whisper)",
    )

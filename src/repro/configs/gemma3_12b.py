"""gemma3-12b [dense] — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family].

Assigned: 48L, d_model=3840, 16H (GQA kv=8), d_ff=15360, vocab=262144.
Pattern: 5 local (sliding-window 1024) layers per 1 global layer.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,           # gemma3 uses head_dim 256 (> d_model/heads)
        d_ff=15360,
        vocab=262144,
        qk_norm=True,
        local_global=(5, 1, 1024),
        rope_base=1_000_000.0,
        source="hf:google/gemma-3-12b-pt",
    )

"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision family].

Assigned: 100L, d_model=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256.
100 layers = 80 self-attention + 20 gated cross-attention layers (one
after every 4 self layers). The ViT vision encoder + projector is
STUBBED per instructions: `input_specs()` feeds precomputed patch
embeddings (b, n_image_tokens, d_model).
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,            # 80 self + 20 cross
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab=128256,
        cross_attn_every=4,
        disc_layers=10,         # 2 groups; local-replica HBM budget (DESIGN.md)
        n_image_tokens=1600,
        rope_base=500_000.0,
        source="hf:meta-llama/Llama-3.2-90B-Vision",
    )

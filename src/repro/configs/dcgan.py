"""The paper's own experimental model: DCGAN [arXiv:1511.06434].

Paper Section IV: generator 3,576,704 parameters, discriminator
2,765,568 parameters — the standard 64x64 DCGAN with nz=100,
ngf=ndf=64, nc=3 (conv weights only, batch-norm affine params included).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DCGANConfig:
    nz: int = 100            # latent dim
    ngf: int = 64            # generator feature maps
    ndf: int = 64            # discriminator feature maps
    nc: int = 3              # image channels
    image_size: int = 64
    source: str = "arXiv:1511.06434 (DCGAN); paper Section IV"


def config() -> DCGANConfig:
    return DCGANConfig()


def small_config() -> DCGANConfig:
    """CPU-scale variant for tests/examples (32x32, thin feature maps)."""
    return DCGANConfig(nz=32, ngf=16, ndf=16, nc=1, image_size=32)

"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

Assigned: 24L, d_model=768, attention-free, d_ff=0, vocab=50280,
ssm_state=128.
"""
from repro.configs.base import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=24,          # d_inner / head_dim = 1536 / 64
        n_kv_heads=24,
        d_ff=0,              # attention-free, no separate FFN (assigned d_ff=0)
        vocab=50280,
        ssm=SSMConfig(d_state=128, head_dim=64, expand=2, n_groups=1),
        source="arXiv:2405.21060 (Mamba-2 / SSD)",
    )

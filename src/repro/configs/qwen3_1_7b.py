"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

Assigned: 28L, d_model=2048, 16H (GQA kv=8), d_ff=6144, vocab=151936.
"""
from repro.configs.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=6144,
        vocab=151936,
        qk_norm=True,
        rope_base=1_000_000.0,
        source="hf:Qwen/Qwen3-8B (1.7B sibling geometry)",
    )

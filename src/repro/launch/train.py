"""Cluster launcher: run protocol training rounds on the production mesh.

On a real TPU pod this is the entry point (one process per host,
jax.distributed.initialize handles the rest). On CPU it degenerates to a
single-device run of the same jitted round — useful with a forced host
device count to exercise either mesh path end-to-end:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --data-dim 8 --rounds 4 --seq-len 64 --batch 32 \
        --layout mesh --fuse-rounds 2

Execution layouts (see launch/steps.build_train_step):

  --layout stacked  GSPMD/pjit rounds, device axis sharded (default);
                    --model-dim is the GSPMD tensor-parallel axis
  --layout mesh     shard_map rounds with explicit collectives; the
                    fused multi-round scan runs INSIDE shard_map. The
                    mesh is (data x model) = (--data-dim x --tp): with
                    --tp > 1 every worker slice is a Megatron TP group
                    on the model axis (feed-forward column/row-parallel,
                    state sharded, Algorithm-2 all-gather payload 1/tp
                    per rank); --tp 1 replicates the model axis exactly
                    like the pre-TP engine. Needs data_dim x tp
                    addressable devices. Checkpoints stay GLOBAL-shaped
                    regardless of --tp (shard_map splits/reassembles),
                    so --resume works across TP widths.

Both layouts chunk `--rounds` into `--fuse-rounds`-sized dispatches with
the state DONATED across chunks; any round count works — the remainder
runs as a shorter final chunk through a per-length compile cache (the
`engine.Trainer._chunk_fn` pattern). Checkpoint writes overlap the next
dispatch: the state is device-copied, the next chunk is dispatched, and
a background thread serializes the copy while the devices compute.

The mesh layout runs EITHER fused algorithm (--algorithm proposed |
fedgan — the latter is the two-net FedGAN baseline inside the same
shard_map scan). Checkpoints serialize the scheduler carry, the
absolute round index, and the simulated wallclock alongside the model
state, so `--resume` continues masks AND the wallclock curve exactly.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch_config, list_archs
from repro.configs.base import MeshConfig, ProtocolConfig, ShapeConfig
from repro.data import make_token_dataset
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh, use_mesh


class AsyncCheckpointer:
    """Overlap checkpoint serialization with the next training dispatch.

    `submit` takes a DEVICE-SIDE copy of the state (so donation of the
    live buffers into the next chunk is safe), returns immediately, and
    writes the copy from a background thread — the host callback blocks
    only on the device copy, never on the next chunk's compute. One
    write is in flight at a time; `finish()` drains the last one.
    """

    def __init__(self, directory: str):
        self.directory = directory
        self._thread = None
        self._error = None

    def submit(self, step_index: int, state, metadata=None):
        from repro.checkpoint import save_checkpoint
        self.finish()
        # device arrays get a device-side copy (donation safety); host
        # scalars (round index, f64 sim wallclock) keep their numpy
        # dtype — jnp.copy would silently downcast f64 with x64 off
        snapshot = jax.tree.map(
            lambda x: jnp.copy(x) if isinstance(x, jax.Array)
            else np.copy(x), state)

        def _write():
            try:
                save_checkpoint(self.directory, step_index, snapshot,
                                metadata=metadata)
            except BaseException as e:   # re-raised at the next finish()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def finish(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint write to {self.directory} failed") from err


def chunk_lengths(rounds: int, fuse: int):
    """`rounds` split into fuse-sized dispatches + a shorter remainder
    chunk (each distinct length costs one compile, served by a cache)."""
    chunks = [fuse] * (rounds // fuse)
    if rounds % fuse:
        chunks.append(rounds % fuse)
    return chunks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU debugging)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--data-dim", type=int, default=4)
    ap.add_argument("--model-dim", type=int, default=None,
                    help="GSPMD model axis, layout stacked only "
                         "(default 2); the mesh layout's model axis "
                         "comes from --tp instead — passing both "
                         "--layout mesh and --model-dim is an error "
                         "rather than a silent reinterpretation")
    ap.add_argument("--tp", type=int, default=1,
                    help="layout mesh only: in-slice tensor parallelism "
                         "— every paper-worker slice is a TP group of "
                         "this width on the 'model' axis (Megatron "
                         "column/row-parallel feed-forward, state "
                         "sharded over model, per-rank Algorithm-2 "
                         "payload 1/tp). 1 = replicate the model axis "
                         "(identical to the pre-TP engine). Checkpoints "
                         "are global-shaped, so --resume works across "
                         "--tp widths")
    ap.add_argument("--schedule", choices=["serial", "parallel"],
                    default="serial")
    ap.add_argument("--layout", choices=["stacked", "mesh"],
                    default="stacked",
                    help="stacked = GSPMD/pjit rounds; mesh = shard_map "
                         "rounds with the fused in-scan engine")
    ap.add_argument("--algorithm", choices=["proposed", "fedgan"],
                    default="proposed",
                    help="proposed = the paper's protocol; fedgan = the "
                         "two-net FedGAN baseline (layout mesh only on "
                         "this builder)")
    ap.add_argument("--fuse-rounds", type=int, default=1,
                    help="rounds fused per XLA dispatch (lax.scan); any "
                         "--rounds works — the remainder runs as a "
                         "shorter final chunk")
    ap.add_argument("--quantize-bits", type=int, default=16,
                    help="uplink quantization width (paper: 16; >=32 "
                         "disables quantization)")
    ap.add_argument("--avg-impl", choices=["pallas", "jnp", "ring"],
                    default="pallas",
                    help="Algorithm-2 collective (layout mesh only): "
                         "pallas = flat all-gather + wavg kernel; jnp = "
                         "per-leaf psum; ring = the quantized-payload "
                         "ppermute ring (kernels/ring_wavg) — the uplink "
                         "stays encoded on the wire, ~2x fewer per-rank "
                         "bytes at 16 bits (tp=1, plain mean, no "
                         "free-riders/byzantine)")
    ap.add_argument("--reducer", default="mean",
                    choices=["mean", "trimmed_mean", "norm_clip", "krum"],
                    help="server aggregation rule (layout mesh only): "
                         "mean = plain weighted average; the robust "
                         "reducers tolerate corrupted uploads at the "
                         "same one-gather + one-Pallas-kernel cost")
    ap.add_argument("--trim", type=int, default=1,
                    help="--reducer trimmed_mean: extreme pairs removed "
                         "per coordinate")
    ap.add_argument("--clip-factor", type=float, default=2.0,
                    help="--reducer norm_clip: clip uploads to this "
                         "multiple of the median participant norm")
    ap.add_argument("--krum-f", type=int, default=1,
                    help="--reducer krum: assumed byzantine count f")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="fault injection: per-round iid worker dropout "
                         "probability (layout mesh only)")
    ap.add_argument("--free-riders", type=int, default=0,
                    help="fault injection: workers replaying the stale "
                         "round-start global model instead of training")
    ap.add_argument("--byzantine", type=int, default=0,
                    help="fault injection: workers uploading scaled "
                         "Gaussian noise")
    ap.add_argument("--byz-scale", type=float, default=10.0,
                    help="byzantine noise scale (x N(0,1))")
    ap.add_argument("--straggler-factor", type=float, default=1.0,
                    help="fault injection: per-worker compute slowdown "
                         "~ U[1, factor] fed into the wallclock model")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the static fault roles (who is a "
                         "free-rider/byzantine/straggler)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint every N rounds (0 = final only); "
                         "writes overlap the next dispatch")
    ap.add_argument("--resume", action="store_true",
                    help="restore the latest checkpoint in --ckpt-dir "
                         "(state + scheduler carry + round index + sim "
                         "wallclock) and continue to --rounds")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host TPU: call jax.distributed.initialize")
    args = ap.parse_args()
    fuse = max(1, args.fuse_rounds)
    if args.algorithm != "proposed" and args.layout != "mesh":
        ap.error("--algorithm fedgan requires --layout mesh on this "
                 "builder (stacked FedGAN runs through core.engine.Trainer)")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")
    if args.tp < 1:
        ap.error("--tp must be >= 1")
    if args.tp > 1 and args.layout != "mesh":
        ap.error("--tp applies to --layout mesh (stacked tensor "
                 "parallelism is --model-dim through GSPMD)")
    if args.layout == "mesh" and args.model_dim is not None:
        ap.error("--model-dim applies to --layout stacked; the mesh "
                 "layout's model axis is --tp (refusing to silently "
                 "reinterpret the mesh shape)")

    from repro.core.faults import FaultConfig
    faults = None
    if (args.dropout > 0.0 or args.free_riders > 0 or args.byzantine > 0
            or args.straggler_factor > 1.0):
        faults = FaultConfig(
            n_devices=args.data_dim, dropout_prob=args.dropout,
            n_free_riders=args.free_riders, n_byzantine=args.byzantine,
            byz_scale=args.byz_scale,
            straggler_factor=args.straggler_factor, seed=args.fault_seed)
    reducer = None
    if args.reducer != "mean":
        from repro.kernels.robust_avg import RobustConfig
        reducer = RobustConfig(method=args.reducer, trim=args.trim,
                               clip_factor=args.clip_factor,
                               krum_f=args.krum_f)
    if (faults is not None or reducer is not None) \
            and args.layout != "mesh":
        ap.error("fault injection / robust reducers run on the fused "
                 "mesh engine: use --layout mesh")
    if (faults is not None or reducer is not None) and args.tp > 1:
        ap.error("faults/robust reducers are not supported under tensor "
                 "parallelism yet; use --tp 1")
    if args.avg_impl != "pallas" and args.layout != "mesh":
        ap.error("--avg-impl selects the mesh layout's Algorithm-2 "
                 "collective: use --layout mesh")
    if args.avg_impl == "ring":
        if args.tp > 1:
            ap.error("--avg-impl ring is not supported under tensor "
                     "parallelism; use --tp 1")
        if reducer is not None:
            ap.error("--avg-impl ring does not compose with robust "
                     "reducers; use --avg-impl pallas")
        if args.free_riders > 0 or args.byzantine > 0:
            ap.error("--avg-impl ring does not compose with "
                     "upload-corrupting faults (free-riders/byzantine); "
                     "use --avg-impl pallas")

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # stacked: (data x model) GSPMD mesh; mesh layout: the model axis IS
    # the in-slice TP width (--tp), every (data, model) slice one rank.
    model_dim = (args.tp if args.layout == "mesh"
                 else (2 if args.model_dim is None else args.model_dim))
    mesh = make_mesh((args.data_dim, model_dim), ("data", "model"))
    mesh_cfg = MeshConfig()
    shape = ShapeConfig("train_cli", args.seq_len, args.batch, "train")

    # per-chunk-length compile cache (the engine._chunk_fn pattern): the
    # remainder chunk reuses everything but the scan length
    step_cache: dict = {}

    def get_step(length: int):
        if length not in step_cache:
            step_cache[length] = steps_mod.build_train_step(
                cfg, shape, mesh, mesh_cfg, schedule=args.schedule,
                fuse_rounds=length, layout=args.layout,
                algorithm=args.algorithm,
                tp=args.tp if args.layout == "mesh" else None,
                pcfg_overrides={"quantize_bits": args.quantize_bits},
                faults=faults, reducer=reducer, avg_impl=args.avg_impl)
        return step_cache[length]

    _, abstract_args = get_step(min(fuse, args.rounds) or 1)

    # materialize real inputs matching the abstract specs
    k_dev = args.data_dim
    n_k = args.batch // k_dev
    toks, _ = make_token_dataset(args.batch, args.seq_len, cfg.vocab)
    tokens = jnp.asarray(toks.reshape(k_dev, n_k, args.seq_len))
    batch = {"tokens": tokens}
    state_abs = abstract_args[0]
    if args.layout == "stacked" and "enc_feats" in abstract_args[1]:
        ef = abstract_args[1]["enc_feats"]
        batch["enc_feats"] = jnp.zeros(ef.shape, ef.dtype)

    from repro.core.engine import mesh_algorithm
    from repro.core.jax_scheduling import JaxScheduler
    from repro.models import gan as gan_model
    pcfg = ProtocolConfig(n_devices=k_dev, n_d=2, n_g=2, sample_size=n_k,
                          server_sample_size=k_dev, schedule=args.schedule)
    weights = jnp.full((k_dev,), float(n_k))
    key = jax.random.PRNGKey(0)
    sched_carry = JaxScheduler(policy="all", n_devices=k_dev).init_carry()

    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    since_ckpt = 0
    wall_total = 0.0
    start_round = 0
    if args.resume:
        from repro.checkpoint import load_checkpoint
        tree, step_idx, meta = load_checkpoint(args.ckpt_dir)
        # NOTE: tp is deliberately NOT checked — checkpoints are
        # global-shaped, so a run may resume at a different TP width.
        for field, want in (("algorithm", args.algorithm),
                            ("layout", args.layout)):
            got = meta.get(field)
            if got is not None and got != want:
                raise SystemExit(
                    f"checkpoint {args.ckpt_dir} was saved with "
                    f"{field}={got}; refusing to resume with "
                    f"--{field.replace('_', '-')} {want}")
        if not (isinstance(tree, dict) and "state" in tree
                and "trainer" in tree):
            raise SystemExit(
                f"checkpoint {args.ckpt_dir} predates --resume support "
                f"(raw state, no trainer record); it cannot restore the "
                f"round index/scheduler carry — restart without --resume")
        # the checkpoint replaces the init entirely — cast against the
        # abstract template instead of materializing a random state
        # only to throw it away
        state = jax.tree.map(lambda a, x: jnp.asarray(x, a.dtype),
                             state_abs, tree["state"])
        extra = tree["trainer"]
        start_round = int(extra["round_index"])
        wall_total = float(extra["sim_wall"])
        sched_carry = jax.tree.map(
            lambda a, x: jnp.asarray(x, a.dtype), sched_carry,
            extra["sched_carry"])
        print(f"resumed {args.ckpt_dir} at round {start_round} "
              f"(sim_wall={wall_total:.1f}s)")
        if start_round >= args.rounds:
            # negative remainders in chunk_lengths would otherwise train
            # a spurious chunk past the requested round count
            print(f"checkpoint already at round {start_round} >= "
                  f"--rounds {args.rounds}; nothing to do")
            return
    else:
        # real init (the dry-run uses ShapeDtypeStructs; here we train)
        # — per-algorithm state init comes from the ONE strategy
        # registry (both CLI algorithms are mesh-capable, so the
        # accessor covers the stacked layout's proposed-only case too)
        algo = mesh_algorithm(args.algorithm)
        state = algo.make_state(
            jax.random.PRNGKey(0), lambda k: gan_model.gan_init(k, cfg),
            pcfg, k_dev)
        # free-rider fault programs carry a stale-upload cache inside the
        # state (and inside checkpoints) — seed it to match state_abs
        from repro.core.faults import attach_fault_state
        state = attach_fault_state(state, faults, algo.payload)
        state = jax.tree.map(
            lambda x, a: jnp.asarray(x, a.dtype), state, state_abs)

    def ckpt_tree(state):
        # scheduler carry + round index + sim wallclock ride along, so a
        # resumed run continues masks and the wallclock curve exactly
        return {"state": state,
                "trainer": {"round_index": np.int64(r),
                            "sim_wall": np.float64(wall_total),
                            "sched_carry": sched_carry}}

    with use_mesh(mesh):
        r = start_round
        for chunk in chunk_lengths(args.rounds - start_round, fuse):
            t0 = time.time()
            step, _ = get_step(chunk)
            if args.layout == "mesh":
                state, sched_carry, out = step(state, sched_carry, tokens,
                                               key, jnp.int32(r))
                metrics = out["metrics"]
                jax.block_until_ready(metrics)
                wall_total += float(np.asarray(out["wallclock_s"]).sum())
            else:
                state, metrics = step(state, batch, weights, jnp.int32(r))
                jax.block_until_ready(metrics)
            dt = time.time() - t0
            # metric keys are per-algorithm (FedGAN's server only
            # averages, so it reports participation, not objectives)
            stats = " ".join(
                f"{k}={np.atleast_1d(np.asarray(v))[-1]:+.4f}"
                for k, v in sorted(metrics.items()))
            label = (f"round {r}" if chunk == 1 else
                     f"rounds {r}..{r + chunk - 1}")
            extra = (f" sim_wall={wall_total:.1f}s"
                     if args.layout == "mesh" else "")
            print(f"{label}: {stats} "
                  f"({dt:.2f}s, {chunk / dt:.1f} rounds/s){extra}")
            r += chunk
            since_ckpt += chunk
            if ckpt and args.ckpt_every and since_ckpt >= args.ckpt_every \
                    and r < args.rounds:
                # device-copy now, write in the background while the
                # next chunk runs on the donated live buffers
                ckpt.submit(r, ckpt_tree(state),
                            metadata={"layout": args.layout,
                                      "algorithm": args.algorithm,
                                      "tp": args.tp})
                since_ckpt = 0

    if ckpt:
        ckpt.finish()
        ckpt.submit(args.rounds, ckpt_tree(state),
                    metadata={"layout": args.layout,
                              "algorithm": args.algorithm,
                              "tp": args.tp})
        ckpt.finish()
        print(f"saved {args.ckpt_dir}")


if __name__ == "__main__":
    main()

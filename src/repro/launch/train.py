"""Cluster launcher: run protocol training rounds on the production mesh.

On a real TPU pod this is the entry point (one process per host,
jax.distributed.initialize handles the rest). On CPU it degenerates to a
single-device run of the same jitted round — useful with
--mesh-debug-devices to exercise the mesh path end-to-end:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --data-dim 16 --model-dim 2 --rounds 2 --seq-len 64 --batch 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch_config, list_archs
from repro.configs.base import MeshConfig, ProtocolConfig, ShapeConfig
from repro.data import make_token_dataset
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_mesh, use_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU debugging)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--data-dim", type=int, default=4)
    ap.add_argument("--model-dim", type=int, default=2)
    ap.add_argument("--schedule", choices=["serial", "parallel"],
                    default="serial")
    ap.add_argument("--fuse-rounds", type=int, default=1,
                    help="rounds fused per XLA dispatch (lax.scan); 1 = "
                         "host loop, >1 = the compiled multi-round driver")
    ap.add_argument("--quantize-bits", type=int, default=16,
                    help="uplink quantization width (paper: 16; >=32 "
                         "disables quantization)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--distributed", action="store_true",
                    help="multi-host TPU: call jax.distributed.initialize")
    args = ap.parse_args()
    fuse = max(1, args.fuse_rounds)
    if args.rounds % fuse:
        ap.error(f"--rounds {args.rounds} must be a multiple of "
                 f"--fuse-rounds {fuse}")

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_arch_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_mesh((args.data_dim, args.model_dim), ("data", "model"))
    mesh_cfg = MeshConfig()
    shape = ShapeConfig("train_cli", args.seq_len, args.batch, "train")
    step, abstract_args = steps_mod.build_train_step(
        cfg, shape, mesh, mesh_cfg, schedule=args.schedule,
        fuse_rounds=fuse,
        pcfg_overrides={"quantize_bits": args.quantize_bits})

    # materialize real inputs matching the abstract specs
    k_dev = args.data_dim
    n_k = args.batch // k_dev
    toks, _ = make_token_dataset(args.batch, args.seq_len, cfg.vocab)
    batch = {"tokens": jnp.asarray(
        toks.reshape(k_dev, n_k, args.seq_len))}
    state_abs = abstract_args[0]
    if "enc_feats" in abstract_args[1]:
        ef = abstract_args[1]["enc_feats"]
        batch["enc_feats"] = jnp.zeros(ef.shape, ef.dtype)

    # real init (the dry-run uses ShapeDtypeStructs; here we train)
    from repro.core import protocol
    from repro.models import gan as gan_model
    pcfg = ProtocolConfig(n_devices=k_dev, n_d=2, n_g=2, sample_size=n_k,
                          server_sample_size=k_dev, schedule=args.schedule)
    state = protocol.make_train_state(
        jax.random.PRNGKey(0), lambda k: gan_model.gan_init(k, cfg), pcfg,
        k_dev)
    state = jax.tree.map(
        lambda x, a: jnp.asarray(x, a.dtype), state, state_abs)
    weights = jnp.full((k_dev,), float(n_k))

    with use_mesh(mesh):
        for r in range(0, args.rounds, fuse):
            t0 = time.time()
            state, metrics = step(state, batch, weights, jnp.int32(r))
            jax.block_until_ready(metrics)
            dt = time.time() - t0
            if fuse == 1:
                print(f"round {r}: disc_obj="
                      f"{float(metrics['disc_objective']):+.4f} "
                      f"gen_obj={float(metrics['gen_objective']):+.4f} "
                      f"({dt:.2f}s)")
            else:
                d = np.asarray(metrics["disc_objective"])
                g = np.asarray(metrics["gen_objective"])
                print(f"rounds {r}..{r + fuse - 1}: disc_obj="
                      f"{d[-1]:+.4f} gen_obj={g[-1]:+.4f} "
                      f"({dt:.2f}s, {fuse / dt:.1f} rounds/s)")

    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, args.rounds, state)
        print(f"saved {args.ckpt_dir}")


if __name__ == "__main__":
    main()

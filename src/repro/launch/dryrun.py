import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production mesh and extract roofline inputs.

MUST be run as its own process (`python -m repro.launch.dryrun ...`):
the XLA_FLAGS line above executes before any jax import so the host
platform exposes 512 placeholder devices.

Outputs one JSON per combination under results/dryrun/.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import math  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import get_arch_config, list_archs, INPUT_SHAPES  # noqa: E402
from repro.configs.base import MeshConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh, use_mesh  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch import analysis  # noqa: E402

# long_500k needs sub-quadratic attention / bounded state (DESIGN.md §3):
LONG_OK = {"mamba2-130m", "zamba2-2.7b", "mixtral-8x22b", "gemma3-12b"}


def combos():
    for arch in list_archs():
        for shape in INPUT_SHAPES.values():
            if shape.name == "long_500k" and arch not in LONG_OK:
                continue
            yield arch, shape.name


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            schedule: str = "serial", tag: str = "",
            variant: str = "") -> dict:
    from repro.launch import variants as variants_mod

    cfg = get_arch_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh_cfg = MeshConfig(multi_pod=multi_pod)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())

    t0 = time.time()
    kw = {"schedule": schedule} if shape.kind == "train" else {}
    cfg, var_kw = variants_mod.apply(cfg, variant)
    if shape.kind == "train":
        kw.update(var_kw)
    step, args = steps_mod.build_step(cfg, shape, mesh, mesh_cfg, **kw)
    with use_mesh(mesh):
        lowered = step.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        result = analysis.analyze_compiled(compiled, n_chips)
        if out_dir:
            # keep the optimized HLO so cost models can be re-run offline
            import gzip
            hlo_dir = os.path.join(os.path.dirname(out_dir) or ".", "hlo")
            os.makedirs(hlo_dir, exist_ok=True)
            hname = (f"{arch.replace('.', '_')}__{shape_name}__"
                     f"{'multi' if multi_pod else 'single'}"
                     f"{'_' + tag if tag else ''}.hlo.txt.gz")
            with gzip.open(os.path.join(hlo_dir, hname), "wt") as f:
                f.write(compiled.as_text())

    result.update({
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips, "schedule": schedule if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    })
    mem = result["memory"]
    peak = mem.get("peak_bytes")
    print(f"[dryrun] {arch} x {shape_name} x "
          f"{'multi' if multi_pod else 'single'}: "
          f"dominant={result['roofline']['dominant']} "
          f"compute={result['roofline']['compute_s']:.3e}s "
          f"memory={result['roofline']['memory_s']:.3e}s "
          f"collective={result['roofline']['collective_s']:.3e}s "
          f"peak/dev={peak/1e9 if peak else float('nan'):.2f}GB "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = (f"{arch.replace('.', '_')}__{shape_name}__"
                 f"{'multi' if multi_pod else 'single'}{suffix}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="input-shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--schedule", default="serial",
                    choices=["serial", "parallel"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="suffix for output files")
    ap.add_argument("--variant", default="",
                    help="perf variant (see repro.launch.variants)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pairs = [(a, s) for a, s in combos()
             if (args.arch in ("all", a)) and (args.shape in ("all", s))]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape_name in pairs:
        for multi in meshes:
            suffix = f"_{args.tag}" if args.tag else ""
            fname = (f"{arch.replace('.', '_')}__{shape_name}__"
                     f"{'multi' if multi else 'single'}{suffix}.json")
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, fname)):
                print(f"[dryrun] skip existing {fname}", flush=True)
                continue
            try:
                run_one(arch, shape_name, multi, args.out,
                        schedule=args.schedule, tag=args.tag,
                        variant=args.variant)
            except Exception:
                print(f"[dryrun] FAILED {arch} x {shape_name} x "
                      f"{'multi' if multi else 'single'}", flush=True)
                traceback.print_exc()
                failures.append((arch, shape_name, multi))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}", flush=True)
        sys.exit(1)
    print("[dryrun] all combinations lowered and compiled OK", flush=True)


if __name__ == "__main__":
    main()

"""Compiled-artifact analysis: roofline terms from the dry-run.

Hardware constants (TPU v5e targets, per the task statement):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

collective_bytes is not in cost_analysis(): we parse the optimized HLO,
build an instruction -> shape table, and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
All-reduce is counted twice (reduce-scatter + all-gather equivalent on a
ring).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
ICI_BW = 50e9               # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

# `%name = bf16[1,2,3]{...}` or tuple results `(bf16[..], f32[..])`
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                       r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum of operand bytes per collective kind (whole-program logical
    bytes; see module docstring for the all-reduce convention)."""
    # instruction result shapes (for operand lookup)
    shapes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, _op = m.groups()
        shapes[name] = _shape_bytes(type_str)

    per_kind: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if kind is None or op.endswith("-start") and False:
            continue
        if op.endswith("-done"):
            continue   # async pair: count the -start only
        # operand list: %arg names inside the call parens
        operands = re.findall(r"%([\w.\-]+)", line.split("(", 1)[1])
        op_bytes = sum(shapes.get(o, 0) for o in operands)
        if op_bytes == 0:
            op_bytes = _shape_bytes(type_str)   # fallback: result shape
        if kind == "all-gather":
            # operand is the shard; traffic ~ gathered result
            op_bytes = max(op_bytes, _shape_bytes(type_str))
        if kind == "all-reduce":
            op_bytes *= 2
        per_kind[kind] += op_bytes
        counts[kind] += 1
    return {"bytes_by_kind": dict(per_kind),
            "counts": dict(counts),
            "total_bytes": float(sum(per_kind.values()))}


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.n_chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "n_chips": self.n_chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
        }


def analyze_compiled(compiled, n_chips: int) -> dict:
    from repro.launch.hlo_costs import hlo_costs

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    text = compiled.as_text()
    # loop-aware costs (xla cost_analysis counts while bodies once).
    # The SPMD module is the PER-DEVICE program (shard shapes), so scale
    # by n_chips to get global quantities for the roofline formulas.
    lc = hlo_costs(text)
    coll = {"bytes_by_kind": {k: v * n_chips
                              for k, v in lc["bytes_by_kind"].items()},
            "counts": lc["counts"],
            "total_bytes": lc["collective_bytes"] * n_chips,
            "raw_uncorrected": parse_collective_bytes(text)["total_bytes"]
            * n_chips}
    roof = Roofline(flops=lc["flops"] * n_chips,
                    hbm_bytes=lc["hbm_bytes"] * n_chips,
                    collective_bytes=lc["collective_bytes"] * n_chips,
                    n_chips=n_chips)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes),
        }
    except Exception as e:  # backend-dependent
        mem_info = {"error": str(e)}
    return {"roofline": roof.as_dict(), "collectives": coll,
            "memory": mem_info,
            "xla_cost_analysis_raw": {
                # while bodies counted once — kept for reference only
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            }}


def model_flops_per_round(n_params_active: int, tokens: int) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) with N = active params."""
    return 6.0 * n_params_active * tokens

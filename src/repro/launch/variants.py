"""Named perf variants for the §Perf hillclimb.

A variant transforms (ArchConfig, step kwargs) before the dry-run
builds/lowers the step, so each hypothesis→change→measure iteration is
one `dryrun --variant <name> --tag <name>` invocation whose JSON lands
next to the baseline for comparison.
"""
from __future__ import annotations

import dataclasses
import re

from repro.configs.base import ArchConfig


def apply(cfg: ArchConfig, variant: str):
    """Returns (cfg, step_kwargs) for a named variant ('' = baseline)."""
    kw: dict = {}
    if not variant:
        return cfg, kw
    for part in variant.split("+"):
        cfg, kw = _apply_one(cfg, kw, part)
    return cfg, kw


def _apply_one(cfg: ArchConfig, kw: dict, name: str):
    if name == "discrep":
        # pin the (vmapped) discriminator residual stream to replicated-
        # within-device-group: weights stay TP; matmuls contract the
        # sharded dim with small activation all-reduces instead of GSPMD
        # re-gathering the weights every layer/microstep.
        from jax.sharding import PartitionSpec as P
        return cfg, {**kw, "act_disc_spec": P(None, None, None)}
    if name == "flashrep":
        # head-sharding-friendly flash layout (repeat kv to full heads)
        return dataclasses.replace(cfg, flash_repeat_kv=True), kw
    if name == "moepin":
        # pin dispatched expert tensors replicated-within-device so expert
        # matmuls do partial-sum ARs instead of dispatch all-gathers
        from repro.nn import moe as moe_mod
        moe_mod.CONSTRAIN_DISPATCH = "replicated"
        return cfg, kw
    if name == "hoist":
        # compute the shared-seed fake batch once per local step (exact
        # same math; K x fewer generator forwards) — see ProtocolConfig
        ov = dict(kw.get("pcfg_overrides") or {})
        ov["hoist_fakes"] = True
        return cfg, {**kw, "pcfg_overrides": ov}
    if name == "fused":
        # fused qkv + fused in|gate projections (fewer TP backward ARs)
        return dataclasses.replace(cfg, fuse_proj=True), kw
    if name == "headpin":
        # flashrep + pin flash q/k/v heads onto the model axis so the
        # whole blockwise attention scan is TP-local (no per-block reshard)
        import repro.nn.attention as attn_mod
        attn_mod.FLASH_HEAD_AXIS = "model"
        return dataclasses.replace(cfg, flash_repeat_kv=True), kw
    if name == "parallel":
        # paper's parallel schedule: the generator update is dataflow-
        # independent of Algorithm 2's all-reduce -> overlappable
        kw = {**kw, "schedule": "parallel"}
        return cfg, kw
    if name == "moe_sort":
        # memory-lean sort dispatch instead of GShard one-hot einsum
        assert cfg.moe is not None
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch="sort")), kw
    if m := re.fullmatch(r"micro(\d+)", name):
        ov = dict(kw.get("pcfg_overrides") or {})
        ov["micro_batch_d"] = int(m.group(1))
        return cfg, {**kw, "pcfg_overrides": ov}
    if m := re.fullmatch(r"nd(\d+)", name):
        ov = dict(kw.get("pcfg_overrides") or {})
        ov["n_d"] = int(m.group(1))
        return cfg, {**kw, "pcfg_overrides": ov}
    if m := re.fullmatch(r"group(\d+)", name):
        # MoE dispatch group size
        assert cfg.moe is not None
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         group_size=int(m.group(1)))), kw
    if m := re.fullmatch(r"cap(\d+)", name):
        # MoE capacity factor (percent)
        assert cfg.moe is not None
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=int(m.group(1)) / 100.0)), kw
    if m := re.fullmatch(r"disc(\d+)", name):
        # discriminator depth (layers)
        return dataclasses.replace(cfg, disc_layers=int(m.group(1))), kw
    raise ValueError(f"unknown variant {name!r}")

"""Loop-aware cost extraction from optimized HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, which
silently underestimates any scanned program (layer scans, n_d/n_g SGD
loops, microbatch accumulation) by the trip count. The optimized HLO
carries `known_trip_count` on while ops, so we parse the module into
computations, build the call graph, and aggregate costs with each while
body multiplied by its trip count.

Extracted per program:
  flops            dot/convolution FLOPs (2*M*N*K), trip-corrected
  hbm_bytes        Σ over materializing instructions of operand+result
                   bytes (fusions are XLA's memory-traffic units; this is
                   a no-reuse traffic model), trip-corrected
  collective_bytes Σ operand bytes of all-gather / all-reduce /
                   reduce-scatter / all-to-all / collective-permute
                   (all-reduce counted twice: RS+AG), trip-corrected
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}\s/*]+?))\s+"
    r"([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n[":]+(\d+)')
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")

_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "copy", "after-all", "partition-id", "replica-id",
             "reshape"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, None
    dims = [int(d) for d in m.group(2).split(",") if d] or [1]
    return m.group(1), dims


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


class HloModule:
    def __init__(self, text: str):
        self.computations: dict[str, list[_Instr]] = {}
        self.entry: str | None = None
        cur = None
        for line in text.splitlines():
            if not line.strip():
                continue
            if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                    continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                self.computations[cur].append(
                    _Instr(m.group(1), m.group(2), m.group(3), line))

        # result-shape table for operand size lookups (global namespace is
        # fine: names are unique within the module dump)
        self.shape_of: dict[str, str] = {}
        for instrs in self.computations.values():
            for ins in instrs:
                self.shape_of[ins.name] = ins.type_str

    # ------------------------------------------------------------------
    def _dot_flops(self, ins: _Instr) -> float:
        # FLOPs = 2 * prod(result dims) * contraction size
        _, rdims = _shape_elems(ins.type_str)
        if rdims is None:
            return 0.0
        operands = re.findall(r"%([\w.\-]+)", ins.line.split("(", 1)[1])
        if not operands:
            return 0.0
        lhs = self.shape_of.get(operands[0], "")
        _, ldims = _shape_elems(lhs)
        if ldims is None:
            return 0.0
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        contract = 1
        if cdims and cdims.group(1):
            for d in cdims.group(1).split(","):
                contract *= ldims[int(d)]
        rprod = 1
        for d in rdims:
            rprod *= d
        return 2.0 * rprod * contract

    def _conv_flops(self, ins: _Instr) -> float:
        _, rdims = _shape_elems(ins.type_str)
        operands = re.findall(r"%([\w.\-]+)", ins.line.split("(", 1)[1])
        if rdims is None or len(operands) < 2:
            return 0.0
        _, kdims = _shape_elems(self.shape_of.get(operands[1], ""))
        if kdims is None:
            return 0.0
        kprod = 1
        for d in kdims:
            kprod *= d
        rprod = 1
        for d in rdims:
            rprod *= d
        # 2 * out_elems * (kernel_elems / out_channels); out channel is the
        # last result dim under our NHWC convention — approximate.
        return 2.0 * rprod * max(kprod // max(rdims[-1], 1), 1)

    def _instr_costs(self, ins: _Instr):
        """(flops, hbm_bytes, collective_bytes_by_kind, called, trip)."""
        flops = 0.0
        hbm = 0.0
        coll: dict[str, float] = {}
        called, trip = None, 1

        if ins.op == "while":
            called = re.search(r"body=%?([\w.\-]+)", ins.line)
            called = called.group(1) if called else None
            t = _TRIP_RE.search(ins.line)
            if t:
                trip = int(t.group(1))
            else:
                cond = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trip = self._trip_from_condition(cond.group(1)) if cond else 1
            return flops, hbm, coll, called, trip
        if ins.op in ("fusion", "call"):
            m = _CALLED_RE.search(ins.line)
            called = m.group(1) if m else None
        if ins.op == "conditional":
            # take the first branch computation as representative
            m = re.search(r"branch_computations=\{%?([\w.\-]+)", ins.line)
            if m:
                called = m.group(1)

        if ins.op == "dot":
            flops = self._dot_flops(ins)
        elif ins.op == "convolution":
            flops = self._conv_flops(ins)

        kind = next((c for c in COLLECTIVES if ins.op.startswith(c)), None)
        if kind and not ins.op.endswith("-done"):
            operands = re.findall(r"%([\w.\-]+)", ins.line.split("(", 1)[1])
            nbytes = sum(_shape_bytes(self.shape_of.get(o, ""))
                         for o in operands)
            if nbytes == 0:
                nbytes = _shape_bytes(ins.type_str)
            if kind == "all-gather":
                nbytes = max(nbytes, _shape_bytes(ins.type_str))
            if kind == "all-reduce":
                nbytes *= 2
            coll[kind] = coll.get(kind, 0.0) + nbytes

        if ins.op not in _SKIP_OPS and ins.op != "while":
            operands = re.findall(r"%([\w.\-]+)", ins.line.split("(", 1)[1])
            result_bytes = _shape_bytes(ins.type_str)
            op_bytes = [_shape_bytes(self.shape_of.get(o, ""))
                        for o in operands]
            root = ins.op
            if ins.op == "fusion" and called in self.computations:
                body = self.computations[called]
                if body:
                    root = body[-1].op   # ROOT is last
            if root == "dynamic-update-slice" or ins.op == "dynamic-update-slice":
                # in-place update (XLA aliases the buffer): traffic is the
                # modified region + small inputs, not the whole cache.
                hbm = 2.0 * sum(bb for bb in op_bytes if bb != result_bytes)
            elif ins.op in ("dynamic-slice", "gather"):
                hbm = 2.0 * result_bytes
            else:
                hbm = result_bytes + sum(op_bytes)

        return flops, hbm, coll, called, trip

    def _trip_from_condition(self, cond_name: str) -> int:
        """Recover a scan's trip count from its `lt(i, N)` condition:
        take the largest integer constant in the condition computation."""
        best = 1
        for ins in self.computations.get(cond_name, []):
            if ins.op == "constant":
                m = re.search(r"constant\((\d+)\)", ins.line)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    # ------------------------------------------------------------------
    def totals(self):
        memo: dict[str, tuple] = {}

        def comp_totals(name: str):
            if name in memo:
                return memo[name]
            memo[name] = (0.0, 0.0, {}, {})  # cycle guard
            flops_t, hbm_t = 0.0, 0.0
            coll_t: dict[str, float] = defaultdict(float)
            cnt_t: dict[str, int] = defaultdict(int)
            for ins in self.computations.get(name, []):
                flops, hbm, coll, called, trip = self._instr_costs(ins)
                flops_t += flops
                hbm_t += hbm
                for k, v in coll.items():
                    coll_t[k] += v
                    cnt_t[k] += 1
                if called and called in self.computations:
                    cf, ch, cc, cn = comp_totals(called)
                    flops_t += trip * cf
                    # fusions are XLA's memory-traffic unit: their internal
                    # ops live in registers/cache — count only the call
                    # site's operands+result (already in `hbm` above).
                    if ins.op != "fusion":
                        hbm_t += trip * ch
                    for k, v in cc.items():
                        coll_t[k] += trip * v
                    for k, v in cn.items():
                        cnt_t[k] += trip * v
            memo[name] = (flops_t, hbm_t, dict(coll_t), dict(cnt_t))
            return memo[name]

        assert self.entry, "no ENTRY computation found"
        flops, hbm, coll, counts = comp_totals(self.entry)
        return {
            "flops": flops,
            "hbm_bytes": hbm,
            "collective_bytes": float(sum(coll.values())),
            "bytes_by_kind": coll,
            "counts": counts,
        }


def hlo_costs(hlo_text: str) -> dict:
    return HloModule(hlo_text).totals()

"""Step builders for the production mesh: one protocol training round,
serving prefill, and serving decode — each returning the jitted function
plus abstract inputs (ShapeDtypeStruct) and shardings, so launch/dryrun.py
can `.lower().compile()` every (architecture x input shape x mesh)
without allocating anything.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ArchConfig, MeshConfig, ProtocolConfig,
                                ShapeConfig)
from repro.core import protocol
from repro.models import gan as gan_model
from repro.models.backbone import init_decode_caches
from repro.models.specs import make_backbone_spec
from repro.sharding import rules

COMPUTE_DTYPE = jnp.bfloat16


def _bf16_floats(tree):
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, COMPUTE_DTYPE)
        return jax.ShapeDtypeStruct(x.shape, x.dtype)
    return jax.tree.map(cast, tree)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def needs_enc(cfg: ArchConfig) -> bool:
    return cfg.family in ("encdec", "vlm")


# per-chip budget for remat carries on the discriminator path (bf16)
_CARRY_BUDGET_BYTES = 1.5e9


def _pick_micro_d(cfg: ArchConfig, m: int, seq: int):
    """Largest divisor of m whose depth-stacked remat carry fits budget."""
    from repro.models.gan import disc_config
    dcfg = disc_config(cfg)
    n_groups = dcfg.n_groups_stack
    per_sample = n_groups * seq * cfg.d_model * 2  # bf16 carry per group
    best = 1
    for micro in range(1, m + 1):
        if m % micro == 0 and micro * per_sample <= _CARRY_BUDGET_BYTES:
            best = micro
    return None if best == m else best


def _enc_len(cfg: ArchConfig) -> int:
    return cfg.enc_seq if cfg.family == "encdec" else cfg.n_image_tokens


# ---------------------------------------------------------------------------
# Training round
# ---------------------------------------------------------------------------

def build_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     mesh_cfg: MeshConfig,
                     pcfg: Optional[ProtocolConfig] = None,
                     schedule: str = "serial",
                     pcfg_overrides: Optional[dict] = None,
                     act_disc_spec: Optional[object] = "default",
                     fuse_rounds: int = 1,
                     layout: str = "stacked",
                     algorithm: str = "proposed",
                     tp: Optional[int] = None,
                     faults=None, reducer=None,
                     avg_impl: str = "pallas"):
    """The protocol round as the pod-scale train step, on either
    execution layout.

    `faults` (core.faults.FaultConfig) injects the hostile-worker
    regime — per-round dropout, stragglers, free-riders, byzantine
    uploads — and `reducer` (a robust method name or
    kernels.robust_avg.RobustConfig) swaps Algorithm 2 for a robust
    aggregate. Both are layout='mesh' features (the fused mesh engine
    owns scheduling + the averaging collective); requesting them on the
    stacked builder raises. `avg_impl` selects the mesh Algorithm-2
    collective ("pallas" flat gather + wavg kernel, "jnp" per-leaf
    psum, or "ring" — the quantized-payload ppermute ring of
    kernels/ring_wavg; tp=1, no robust/corrupting faults).

    The paper's K devices = the mesh's device axes (pod x data slices).
    global_batch rows of real data are the per-round union of local
    samples: K * n_k = global_batch.

    layout="stacked" (default) — the stacked/GSPMD path: `gan_round`
        under pjit with explicit NamedShardings; the device axis is a
        sharded leading dim and Algorithm 2's weighted mean lowers to
        the ICI all-reduce. fuse_rounds > 1 wraps the round body in a
        `lax.scan` over consecutive seeds, and the state is DONATED so
        launch/train.py chains chunks without copies. Returns
        (step, (state, batch, weights, seed)) with step jitted;
        step(state, batch, weights, seed) -> (state, metrics).
        Proposed protocol only (the FedGAN baseline runs stacked through
        `core.engine.Trainer`, not the pod-scale step builder).

    layout="mesh" — the explicit-collective path: `fuse_rounds` complete
        rounds (Step 1 scheduling + channel timing + quantized uplink +
        Pallas-wavg averaging + wallclock) run INSIDE `jax.shard_map` as
        one donated `lax.scan` dispatch via
        `core.shard_round.shard_rounds_scan` (algorithm="proposed") or
        `core.shard_round.fedgan_shard_rounds_scan`
        (algorithm="fedgan": per-device joint D+G local iterations, the
        two-net uplink payload, both networks averaged). With `tp > 1`
        (default: inferred from the mesh's `model` axis) each worker
        slice is a TENSOR-PARALLEL group: the backbone's feed-forward
        blocks run Megatron column/row-parallel with in-slice
        collectives on the `model` axis (make_backbone_spec(tp_axis=),
        sharding.rules.tp_leaf_dim name rules), the state enters
        shard_map split over `model`, and each TP rank averages just
        its parameter shard — the Algorithm-2 all-gather payload
        shrinks by the TP factor. tp=1 replicates the model axis
        (exactly the pre-TP engine). Returns (step, (state, sched_carry,
        tokens, key, start_round)); step(...) -> (state, sched_carry,
        out) where out stacks per-round metrics/wallclock_s/mask/
        weights. Encoder-fed families (encdec/vlm) are not supported on
        this layout.

    The round applies the paper's quantized uplink per device
    (pcfg.quantize_bits, default 16) inside the round math; override
    with pcfg_overrides={"quantize_bits": ...} (>= 32 disables it).
    Under GSPMD the per-device quantization stays embarrassingly
    parallel; under shard_map it is keyed by the slice's axis index, so
    both layouts quantize bitwise-identically.
    """
    plan = rules.plan_for(cfg, mesh_cfg)
    k_dev = math.prod(mesh.shape[a] for a in plan.dev_axes)
    assert shape.global_batch % k_dev == 0
    n_k = shape.global_batch // k_dev
    seq = shape.seq_len
    if pcfg is None:
        # Server sample size M = K_dev so the generator update ("the
        # distributed server") batch-shards exactly over the device axes.
        # Microbatching (gradient accumulation) caps remat-carry memory
        # at disc_depth x micro x seq x d_model per chip.
        pcfg = ProtocolConfig(
            n_devices=k_dev, n_d=5, n_g=5,
            sample_size=n_k, server_sample_size=k_dev,
            micro_batch_d=_pick_micro_d(cfg, n_k, seq),
            schedule=schedule)
    if pcfg_overrides:
        pcfg = dataclasses.replace(pcfg, **pcfg_overrides)

    enc = needs_enc(cfg)
    if layout == "mesh":
        return _build_mesh_train_step(cfg, shape, mesh, plan, pcfg,
                                      fuse_rounds, algorithm, tp,
                                      faults=faults, reducer=reducer,
                                      avg_impl=avg_impl)
    if layout != "stacked":
        raise ValueError(f"unknown layout {layout!r}")
    if faults is not None or reducer is not None:
        raise ValueError(
            "faults/reducer require layout='mesh' (the fused mesh engine "
            "owns scheduling and the averaging collective); the stacked "
            "pod-scale step has no fault machinery")
    if avg_impl != "pallas":
        raise ValueError(
            f"avg_impl={avg_impl!r} selects the mesh layout's explicit "
            f"Algorithm-2 collective; layout='stacked' lowers the "
            f"averaging through GSPMD (use layout='mesh')")
    if tp not in (None, 1):
        raise ValueError(
            f"tp={tp} applies to layout='mesh' only; on the stacked "
            f"layout tensor parallelism comes from the mesh's 'model' "
            f"axis through GSPMD (rules.param_specs)")
    if algorithm != "proposed":
        raise ValueError(
            f"build_train_step(layout='stacked') runs the proposed "
            f"protocol only (got algorithm {algorithm!r}); FedGAN runs "
            f"stacked through core.engine.Trainer, or on this builder "
            f"with layout='mesh'")

    stacked_disc_specs = None  # filled after abstract init

    # Generator activations batch-shard over the device axes (M = K_dev);
    # discriminator activations stay batch-local to their device group
    # (heads/ff spread over `model` by the param rules), with microbatched
    # gradient accumulation bounding the remat carries.
    act_gen = P(plan.dev_axes, None, None)
    act_disc = None if act_disc_spec == "default" else act_disc_spec

    def train_step(state, batch, weights, seed):
        round_key = jax.random.PRNGKey(seed)
        enc_feats = batch.get("enc_feats")
        spec = make_backbone_spec(
            cfg, seq,
            enc_feats_fn=(lambda n: enc_feats[:n]) if enc else None,
            act_spec_gen=act_gen, act_spec_disc=act_disc,
            dtype=COMPUTE_DTYPE)
        constrain = None
        if stacked_disc_specs is not None:
            constrain = lambda tree: jax.lax.with_sharding_constraint(
                tree, _named(mesh, stacked_disc_specs))
        return protocol.gan_round(spec, pcfg, state, batch["tokens"],
                                  weights, round_key,
                                  constrain_stacked=constrain)

    if fuse_rounds > 1:
        one_round = train_step

        def train_step(state, batch, weights, seed):
            def body(s, r):
                return one_round(s, batch, weights, r)
            return jax.lax.scan(body, state,
                                seed + jnp.arange(fuse_rounds))

    # ---- abstract state & inputs -------------------------------------
    def init_fn(key):
        return gan_model.gan_init(key, cfg)

    state_abs = jax.eval_shape(
        lambda: protocol.make_train_state(jax.random.PRNGKey(0), init_fn,
                                          pcfg, k_dev))
    state_abs = _bf16_floats(state_abs)

    batch_abs = {"tokens": jax.ShapeDtypeStruct((k_dev, n_k, seq), jnp.int32)}
    if enc:
        m = max(pcfg.sample_size, pcfg.server_sample_size)
        batch_abs["enc_feats"] = jax.ShapeDtypeStruct(
            (m, _enc_len(cfg), cfg.d_model), COMPUTE_DTYPE)
    weights_abs = jax.ShapeDtypeStruct((k_dev,), jnp.float32)
    seed_abs = jax.ShapeDtypeStruct((), jnp.int32)

    state_sp = rules.state_specs(state_abs, mesh, plan,
                                 gen_fsdp=plan.fsdp_axes is not None)
    stacked_disc_specs = jax.tree.map(
        lambda s: P(plan.dev_axes, *s),
        rules.param_specs(state_abs["disc"], mesh, plan),
        is_leaf=lambda s: isinstance(s, P))

    batch_sp = {"tokens": rules.data_spec(plan)}
    if enc:
        batch_sp["enc_feats"] = rules.enc_feats_spec(cfg, mesh, plan)
    in_shardings = (_named(mesh, state_sp), _named(mesh, batch_sp),
                    NamedSharding(mesh, P()), NamedSharding(mesh, P()))
    out_shardings = (_named(mesh, state_sp), None)

    step = jax.jit(train_step, in_shardings=in_shardings,
                   out_shardings=out_shardings, donate_argnums=(0,))
    args = (state_abs, batch_abs, weights_abs, seed_abs)
    return step, args


def _build_mesh_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh, plan,
                           pcfg: ProtocolConfig, fuse_rounds: int,
                           algorithm: str = "proposed",
                           tp: Optional[int] = None,
                           faults=None, reducer=None,
                           avg_impl: str = "pallas"):
    """layout="mesh" of `build_train_step`: `fuse_rounds` complete rounds
    per dispatch inside shard_map, state + scheduler carry donated.
    algorithm selects the per-slice round body (proposed | fedgan);
    tp > 1 (default: the mesh's `model` axis size) runs each worker
    slice as a Megatron TP group over that axis. `faults`/`reducer`
    thread the hostile-worker regime into the fused scan (tp=1 only)."""
    from repro.core import faults as faults_lib
    from repro.core import shard_round
    from repro.core.channel import ChannelConfig
    from repro.core.engine import mesh_algorithm
    from repro.core.jax_channel import JaxChannel
    from repro.core.jax_scheduling import JaxScheduler
    from repro.kernels.robust_avg import RobustConfig

    if needs_enc(cfg):
        raise NotImplementedError(
            "layout='mesh' does not support encoder-fed architectures "
            "(encdec/vlm) yet; use layout='stacked'")
    algo = mesh_algorithm(algorithm)
    rounds_scan, make_state = algo.mesh_rounds_scan, algo.make_state
    k_dev = math.prod(mesh.shape[a] for a in plan.dev_axes)
    assert shape.global_batch % k_dev == 0
    n_k = shape.global_batch // k_dev
    seq = shape.seq_len
    if tp is None:
        tp = mesh.shape["model"] if "model" in mesh.axis_names else 1
    else:
        from repro.launch.mesh import tp_mesh_error
        err = tp_mesh_error(mesh, tp)
        if err:
            raise ValueError(err)
    tp_axis = plan.tp_axis if tp > 1 else None

    # act specs are GSPMD sharding constraints — inside shard_map the
    # device axes are manual, so the spec-free backbone is used; under
    # tp > 1 the spec's feed-forward math is Megatron-parallel over the
    # model axis instead.
    spec = make_backbone_spec(cfg, seq, dtype=COMPUTE_DTYPE,
                              tp_axis=tp_axis)
    if isinstance(reducer, str):
        reducer = None if reducer == "mean" else RobustConfig(method=reducer)
    if faults is not None and faults.n_devices != k_dev:
        raise ValueError(
            f"faults.n_devices={faults.n_devices} must match the mesh's "
            f"device-axes size {k_dev}")
    # Shared contract checks (one definition, in core/shard_round.py).
    shard_round.check_faults_tp(faults, reducer, tp_axis, tp)
    shard_round.check_ring_support(avg_impl, plan.dev_axes, tp_axis, tp,
                                   faults, reducer)
    channel = JaxChannel(ChannelConfig(n_devices=k_dev))
    scheduler = JaxScheduler(policy=pcfg.scheduler, n_devices=k_dev,
                             ratio=pcfg.scheduling_ratio)
    step = rounds_scan(spec, pcfg, mesh, max(1, fuse_rounds),
                       channel=channel, scheduler=scheduler,
                       device_axes=plan.dev_axes, avg_impl=avg_impl,
                       tp_axis=tp_axis, tp=tp,
                       faults=faults, robust=reducer)

    def init_fn(key):
        return gan_model.gan_init(key, cfg)

    state_abs = _bf16_floats(jax.eval_shape(
        lambda: faults_lib.attach_fault_state(
            make_state(jax.random.PRNGKey(0), init_fn, pcfg, k_dev),
            faults, algo.payload)))
    carry_abs = jax.eval_shape(scheduler.init_carry)
    tokens_abs = jax.ShapeDtypeStruct((k_dev, n_k, seq), jnp.int32)
    key_abs = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    start_abs = jax.ShapeDtypeStruct((), jnp.int32)
    return step, (state_abs, carry_abs, tokens_abs, key_abs, start_abs)


# ---------------------------------------------------------------------------
# Serving: prefill
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                       mesh_cfg: MeshConfig):
    plan = rules.plan_for(cfg, mesh_cfg)
    b, s = shape.global_batch, shape.seq_len
    enc = needs_enc(cfg)

    def prefill_step(gen_params, batch):
        out = gan_model.generator_lm_apply(
            gen_params, cfg, batch["tokens"], mode="prefill",
            enc_feats=batch.get("enc_feats"), remat=False,
            prefill_cache_len=s)
        # last-position logits only (next-token) — standard prefill output
        logits = out["logits"][:, -1, :]
        return logits, out["caches"]

    gen_abs = _bf16_floats(jax.eval_shape(
        lambda: gan_model.generator_init(jax.random.PRNGKey(0), cfg)))
    batch_abs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if enc:
        batch_abs["enc_feats"] = jax.ShapeDtypeStruct(
            (b, _enc_len(cfg), cfg.d_model), COMPUTE_DTYPE)

    # big generators 2D-shard weights over (data x model) for serving —
    # GSPMD contracts the sharded dim with a small-activation all-reduce
    gen_sp = rules.param_specs(gen_abs, mesh, plan,
                               fsdp=plan.fsdp_axes is not None)
    dev = plan.dev_axes
    tok_sp = P(dev) if b % math.prod(mesh.shape[a] for a in dev) == 0 else P()
    batch_sp = {"tokens": tok_sp}
    if enc:
        batch_sp["enc_feats"] = P(tok_sp[0] if tok_sp else None)

    caches_abs = jax.eval_shape(
        lambda: init_decode_caches(cfg, b, s, dtype=COMPUTE_DTYPE))
    cache_sp = rules.cache_specs(cfg, caches_abs, b, mesh, plan)

    in_shardings = (_named(mesh, gen_sp), _named(mesh, batch_sp))
    out_shardings = (None, _named(mesh, cache_sp))
    step = jax.jit(prefill_step, in_shardings=in_shardings,
                   out_shardings=out_shardings)
    return step, (gen_abs, batch_abs)


# ---------------------------------------------------------------------------
# Serving: single-token decode against a seq_len cache
# ---------------------------------------------------------------------------

def build_decode_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
                      mesh_cfg: MeshConfig):
    plan = rules.plan_for(cfg, mesh_cfg)
    b, s = shape.global_batch, shape.seq_len

    def decode_step(gen_params, token, caches, cache_index):
        out = gan_model.generator_lm_apply(
            gen_params, cfg, token, mode="decode", caches=caches,
            cache_index=cache_index, remat=False)
        return out["logits"][:, 0, :], out["caches"]

    gen_abs = _bf16_floats(jax.eval_shape(
        lambda: gan_model.generator_init(jax.random.PRNGKey(0), cfg)))
    token_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    caches_abs = jax.eval_shape(
        lambda: init_decode_caches(cfg, b, s, dtype=COMPUTE_DTYPE))
    idx_abs = jax.ShapeDtypeStruct((), jnp.int32)

    gen_sp = rules.param_specs(gen_abs, mesh, plan,
                               fsdp=plan.fsdp_axes is not None)
    cache_sp = rules.cache_specs(cfg, caches_abs, b, mesh, plan)
    dev = plan.dev_axes
    tok_sp = P(dev) if b % math.prod(mesh.shape[a] for a in dev) == 0 else P()

    in_shardings = (_named(mesh, gen_sp),
                    NamedSharding(mesh, tok_sp),
                    _named(mesh, cache_sp),
                    NamedSharding(mesh, P()))
    out_shardings = (None, _named(mesh, cache_sp))
    step = jax.jit(decode_step, in_shardings=in_shardings,
                   out_shardings=out_shardings)
    return step, (gen_abs, token_abs, caches_abs, idx_abs)


# ---------------------------------------------------------------------------

def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh,
               mesh_cfg: MeshConfig, **kw):
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, mesh_cfg, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh, mesh_cfg)
    if shape.kind == "decode":
        return build_decode_step(cfg, shape, mesh, mesh_cfg)
    raise ValueError(shape.kind)


def input_specs(cfg: ArchConfig, shape: ShapeConfig, mesh, mesh_cfg):
    """ShapeDtypeStruct stand-ins for every model input of this step."""
    _, args = build_step(cfg, shape, mesh, mesh_cfg)
    return args

"""Serve a trained generator: continuous-batching decode CLI.

Loads a GLOBAL-shaped training checkpoint (any `--tp` width it was
trained at — checkpoints are reassembled to global shapes on save, see
launch/train.py) and serves it through `repro.serving.ServingEngine` at
any serving `--tp`, with the paged KV/SSM cache on by default:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --ckpt-dir runs/q17 --demo 8 --max-new 16

    # tensor-parallel serving over 2 forced host devices, dense cache
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --reduced --tp 2 --block-size 0 --demo 4

Without `--ckpt-dir` the generator is randomly initialised (useful for
smoke runs and latency measurement). `--block-size 0` disables paging
and reserves dense per-slot `max_len` caches; otherwise the block pool
defaults to the worst case (`batch * ceil(max_len/block) + 1` blocks)
and can be capped with `--n-blocks` to bound memory — the engine queues
admissions when the pool is exhausted instead of failing.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch_config, list_archs
from repro.models import gan
from repro.serving import Request, ServingEngine


def load_generator_params(ckpt_dir: str, step=None):
    """Extract generator params from a training checkpoint tree.

    Accepts the Trainer layout ({"state": {"gen": ...}}), a bare
    {"gen": ...} tree, or raw generator params.
    """
    from repro.checkpoint import load_checkpoint
    tree, step, _ = load_checkpoint(ckpt_dir, step)
    if "state" in tree and "gen" in tree["state"]:
        params = tree["state"]["gen"]
    elif "gen" in tree:
        params = tree["gen"]
    else:
        params = tree
    return jax.tree.map(jax.numpy.asarray, params), step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced (test-size) config")
    ap.add_argument("--ckpt-dir", default="",
                    help="load generator from this checkpoint directory "
                         "(global-shaped; any training tp width)")
    ap.add_argument("--step", type=int, default=None,
                    help="checkpoint step (default: latest)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width for serving; needs tp "
                         "addressable devices")
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slots (max concurrent requests)")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged-cache block size; 0 = dense caches")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="cap the paged block pool (default worst-case)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--demo", type=int, default=4,
                    help="serve N random demo prompts and print tokens")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.ckpt_dir:
        params, step = load_generator_params(args.ckpt_dir, args.step)
        print(f"loaded generator from {args.ckpt_dir} @ step {step}")
    else:
        params = gan.generator_init(jax.random.PRNGKey(args.seed), cfg)
        print("no --ckpt-dir: serving a randomly initialised generator")

    block = args.block_size if args.block_size > 0 else None
    engine = ServingEngine(cfg, params, batch_size=args.batch,
                           max_len=args.max_len, block_size=block,
                           n_blocks=args.n_blocks,
                           prefill_chunk=args.prefill_chunk,
                           seed=args.seed, tp=args.tp)
    print(f"engine: arch={args.arch} tp={args.tp} slots={args.batch} "
          f"max_len={args.max_len} "
          f"cache={'paged/' + str(block) if block else 'dense'} "
          f"({engine.cache_bytes()} bytes)")

    rng = np.random.default_rng(args.seed)
    for i in range(args.demo):
        prompt = rng.integers(1, cfg.vocab, rng.integers(4, 17))
        engine.submit(Request(rid=i, prompt=prompt.astype(np.int32),
                              max_new_tokens=args.max_new,
                              temperature=args.temperature))
    t0 = time.perf_counter()
    finished = engine.run()
    wall = time.perf_counter() - t0
    n_tok = sum(len(r.out_tokens) for r in finished)
    for req in sorted(finished, key=lambda r: r.rid):
        print(f"  rid={req.rid}: {req.out_tokens}")
    for req in engine.rejected:
        print(f"  rid={req.rid}: REJECTED ({req.failed})")
    print(f"{len(finished)} requests, {n_tok} tokens in {wall:.2f}s "
          f"({n_tok / wall:.1f} tok/s), {engine.dispatch_count} steps, "
          f"{engine.compile_count} compiles")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

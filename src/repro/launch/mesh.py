"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; dryrun.py sets
XLA_FLAGS before importing anything).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over host devices for CPU tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=_auto(2))


def device_axes(multi_pod: bool):
    """Mesh axes that play the paper's K devices."""
    return ("pod", "data") if multi_pod else ("data",)

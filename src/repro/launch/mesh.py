"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; dryrun.py sets
XLA_FLAGS before importing anything).

jax-version compatibility: `AxisType` / `make_mesh(axis_types=...)` /
`jax.sharding.set_mesh` only exist in newer jax. On older releases
(e.g. 0.4.x) the helpers here fall back to plain meshes and the Mesh
context manager, which are semantically equivalent for this codebase
(every step passes explicit NamedShardings).
"""
from __future__ import annotations

import jax

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def _auto(n):
    if _HAS_AXIS_TYPES:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types when the installed jax has them."""
    types = _auto(len(shape))
    if types is not None:
        return jax.make_mesh(shape, axes, axis_types=types)
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating `mesh`: jax.sharding.set_mesh on new
    jax, the Mesh context manager on old jax."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """jax.shard_map on new jax; jax.experimental.shard_map on 0.4.x
    (where the replication-checker kwarg is `check_rep`, not `check_vma`).
    The ONE compat wrapper — the round engine and the serving engine both
    route manual-mesh bodies through here."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def devices_error(n: int, context: str = "--layout mesh"):
    """The shared mesh-entry-point guard: the actionable message when
    fewer than `n` devices are addressable, else None. Callers check
    BEFORE any dataset/compile work so a missing XLA_FLAGS fails fast
    with the fix, not deep in jax.make_mesh."""
    have = len(jax.devices())
    if have >= n:
        return None
    return (f"{context} needs >= {n} devices, have {have} (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n})")


def tp_mesh_error(mesh, tp: int):
    """The shared tp-vs-mesh contract: in-slice tensor parallelism of
    width `tp` needs a 'model' axis of exactly that size. Returns the
    actionable message, or None when the mesh satisfies it — the ONE
    definition `core.engine.Trainer` and `launch.steps` both check."""
    if tp <= 1:
        return None
    if "model" not in mesh.axis_names or mesh.shape["model"] != tp:
        return (f"tp={tp} needs a mesh with a 'model' axis of size {tp} "
                f"(got axes {mesh.axis_names} shape {dict(mesh.shape)})")
    return None


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over host devices for CPU tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count set by the caller)."""
    return make_mesh((n_data, n_model), ("data", "model"))


def device_axes(multi_pod: bool):
    """Mesh axes that play the paper's K devices."""
    return ("pod", "data") if multi_pod else ("data",)

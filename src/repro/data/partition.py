"""Device-shard partitioning.

The paper randomly partitions each dataset into equal shards (Section
IV). A Dirichlet(alpha) label-skew partitioner is provided for non-iid
ablations (the regime where discriminator-only averaging is most
stressed).
"""
from __future__ import annotations

import numpy as np


def partition_iid(data: np.ndarray, n_devices: int, *, seed: int = 0):
    """Random equal split -> (K, n_k, ...). Drops the remainder."""
    rng = np.random.default_rng(seed)
    n = data.shape[0]
    per = n // n_devices
    idx = rng.permutation(n)[: per * n_devices]
    return data[idx].reshape((n_devices, per) + data.shape[1:])


def partition_dirichlet(data: np.ndarray, labels: np.ndarray,
                        n_devices: int, *, alpha: float = 0.5,
                        seed: int = 0):
    """Label-skew split: each class is spread over devices by a
    Dirichlet(alpha) draw; shards are then trimmed to equal size."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    buckets: list[list[int]] = [[] for _ in range(n_devices)]
    for c in classes:
        idx = np.where(labels == c)[0]
        rng.shuffle(idx)
        probs = rng.dirichlet(alpha * np.ones(n_devices))
        splits = (np.cumsum(probs)[:-1] * len(idx)).astype(int)
        for dev, part in enumerate(np.split(idx, splits)):
            buckets[dev].extend(part.tolist())
    per = min(len(b) for b in buckets)
    assert per > 0, "a device received no data; raise alpha or n"
    out = np.stack([data[rng.permutation(np.asarray(b))[:per]]
                    for b in buckets])
    return out


def partition(data: np.ndarray, n_devices: int, *, labels=None,
              kind: str = "iid", alpha: float = 0.5, seed: int = 0):
    if kind == "iid":
        return partition_iid(data, n_devices, seed=seed)
    if kind == "dirichlet":
        assert labels is not None
        return partition_dirichlet(data, labels, n_devices, alpha=alpha,
                                   seed=seed)
    raise ValueError(kind)

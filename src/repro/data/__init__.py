from repro.data.synthetic import (
    make_image_dataset,
    make_token_dataset,
    DATASET_SPECS,
)
from repro.data.partition import partition_iid, partition_dirichlet, partition

"""Synthetic stand-ins for the paper's datasets.

The container is offline, so CelebA / CIFAR-10 / RSNA Pneumonia are
modeled by synthetic generators with matched geometry and a controlled
mode structure (a Gaussian mixture over low-frequency image patterns).
What matters for reproducing the paper's *relative* claims (schedule A
converges faster than B; FedGAN uploads 2x bytes; partial scheduling
beats stragglers) is a stationary multi-modal distribution that a DCGAN
can approach — not photographic content. DESIGN.md records this
substitution.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ImageDatasetSpec:
    name: str
    image_size: int
    channels: int
    n_modes: int


DATASET_SPECS = {
    # paper's three datasets, geometry-matched
    "celeba": ImageDatasetSpec("celeba", 64, 3, 8),
    "cifar10": ImageDatasetSpec("cifar10", 32, 3, 10),
    "rsna": ImageDatasetSpec("rsna", 64, 1, 4),
    # tiny variants for CPU tests
    "celeba32": ImageDatasetSpec("celeba32", 32, 3, 8),
    "rsna32": ImageDatasetSpec("rsna32", 32, 1, 4),
    "toy": ImageDatasetSpec("toy", 32, 1, 4),
}


def _mode_pattern(rng: np.random.Generator, size: int, channels: int):
    """A smooth random pattern: sum of a few low-frequency 2-D cosines."""
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    img = np.zeros((size, size, channels), dtype=np.float64)
    for _ in range(4):
        fy, fx = rng.uniform(0.5, 3.0, 2)
        phase_y, phase_x = rng.uniform(0, 2 * np.pi, 2)
        amp = rng.uniform(0.3, 1.0, channels)
        wave = np.cos(2 * np.pi * fy * yy / size + phase_y) * \
            np.cos(2 * np.pi * fx * xx / size + phase_x)
        img += wave[..., None] * amp
    return img


def make_image_dataset(name: str, n: int, *, seed: int = 0,
                       noise: float = 0.15):
    """Returns (images (n, H, W, C) float32 in [-1, 1], mode_labels (n,))."""
    spec = DATASET_SPECS[name]
    rng = np.random.default_rng(seed)
    modes = np.stack([_mode_pattern(rng, spec.image_size, spec.channels)
                      for _ in range(spec.n_modes)])
    labels = rng.integers(0, spec.n_modes, n)
    imgs = modes[labels] + noise * rng.standard_normal(
        (n, spec.image_size, spec.image_size, spec.channels))
    imgs = np.tanh(imgs).astype(np.float32)   # squash into (-1, 1)
    return imgs, labels.astype(np.int32)


def make_token_dataset(n: int, seq_len: int, vocab: int, *, seed: int = 0,
                       n_modes: int = 8, order: int = 2):
    """Synthetic token sequences from a mixture of Markov chains — the
    text-world analogue of the image mixture (for backbone-GAN training).
    Returns (tokens (n, seq_len) int32, mode_labels (n,))."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_modes, n)
    # per-mode sparse transition structure
    out = np.empty((n, seq_len), dtype=np.int32)
    branch = max(2, vocab // 16)
    tables = rng.integers(0, vocab, (n_modes, vocab, branch))
    for i in range(n):
        t = tables[labels[i]]
        seq = np.empty(seq_len, dtype=np.int64)
        seq[0] = rng.integers(0, vocab)
        choices = rng.integers(0, branch, seq_len)
        for j in range(1, seq_len):
            seq[j] = t[seq[j - 1], choices[j]]
        out[i] = seq
    return out, labels.astype(np.int32)

from repro.models.backbone import (
    backbone_init,
    backbone_apply,
    encoder_init,
    encoder_apply,
    init_decode_caches,
    count_params,
)
from repro.models.gan import (
    generator_init,
    generator_apply,
    generator_lm_init,
    generator_lm_apply,
    discriminator_init,
    discriminator_apply,
    gan_init,
)
from repro.models import dcgan

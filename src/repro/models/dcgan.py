"""DCGAN — the paper's experimental model [arXiv:1511.06434].

With the default config (nz=100, ngf=ndf=64, nc=3, 64x64) the parameter
counts match the paper's Section IV exactly:
  generator     3,576,704
  discriminator 2,765,568
(bias-free convs; batch-norm scale+bias counted).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro import nn
from repro.configs.dcgan import DCGANConfig


def _n_stages(image_size: int) -> int:
    n = int(math.log2(image_size)) - 2      # 64 -> 4, 32 -> 3
    assert 2 ** (n + 2) == image_size, "image_size must be a power of two >= 8"
    return n


def generator_init(key, cfg: DCGANConfig):
    n = _n_stages(cfg.image_size)
    chain = [cfg.ngf * 2 ** k for k in range(n - 1, -1, -1)]  # e.g. [512,256,128,64]
    keys = jax.random.split(key, n + 1)
    layers = []
    # initial: z (1x1) -> 4x4 x chain[0]
    layers.append({"conv": nn.conv_transpose2d_init(keys[0], cfg.nz, chain[0], 4),
                   "bn": nn.batchnorm_init(chain[0])})
    for i in range(n - 1):
        layers.append({"conv": nn.conv_transpose2d_init(keys[i + 1], chain[i], chain[i + 1], 4),
                       "bn": nn.batchnorm_init(chain[i + 1])})
    layers.append({"conv": nn.conv_transpose2d_init(keys[n], chain[-1], cfg.nc, 4)})
    return {"layers": layers}


def generator_apply(params, cfg: DCGANConfig, z):
    """z: (b, nz) -> images (b, H, W, nc) in [-1, 1]."""
    x = z.reshape(z.shape[0], 1, 1, cfg.nz)
    layers = params["layers"]
    x = nn.conv_transpose2d_apply(layers[0]["conv"], x, stride=1, padding=0)
    x = jax.nn.relu(nn.batchnorm_apply(layers[0]["bn"], x))
    for layer in layers[1:-1]:
        x = nn.conv_transpose2d_apply(layer["conv"], x, stride=2, padding=1)
        x = jax.nn.relu(nn.batchnorm_apply(layer["bn"], x))
    x = nn.conv_transpose2d_apply(layers[-1]["conv"], x, stride=2, padding=1)
    return jnp.tanh(x)


def discriminator_init(key, cfg: DCGANConfig):
    n = _n_stages(cfg.image_size)
    chain = [cfg.ndf * 2 ** k for k in range(n)]              # e.g. [64,128,256,512]
    keys = jax.random.split(key, n + 1)
    layers = [{"conv": nn.conv2d_init(keys[0], cfg.nc, chain[0], 4)}]  # no BN on 1st
    for i in range(n - 1):
        layers.append({"conv": nn.conv2d_init(keys[i + 1], chain[i], chain[i + 1], 4),
                       "bn": nn.batchnorm_init(chain[i + 1])})
    layers.append({"conv": nn.conv2d_init(keys[n], chain[-1], 1, 4)})
    return {"layers": layers}


def discriminator_apply(params, cfg: DCGANConfig, images):
    """images: (b, H, W, nc) -> logits (b,)."""
    x = images
    layers = params["layers"]
    x = jax.nn.leaky_relu(nn.conv2d_apply(layers[0]["conv"], x), 0.2)
    for layer in layers[1:-1]:
        x = nn.conv2d_apply(layer["conv"], x)
        x = jax.nn.leaky_relu(nn.batchnorm_apply(layer["bn"], x), 0.2)
    x = nn.conv2d_apply(layers[-1]["conv"], x, stride=1, padding=0)
    return x.reshape(x.shape[0])


def gan_init(key, cfg: DCGANConfig):
    kg, kd = jax.random.split(key)
    return {"gen": generator_init(kg, cfg), "disc": discriminator_init(kd, cfg)}

"""GAN wrappers around the backbone zoo.

For every assigned architecture the protocol trains a *backbone-GAN*:

  Generator   noise z (b, s, d_z) --z_proj--> backbone --out_proj-->
              synthetic embedding sequence (b, s, d_model).
              The same parameter set also carries an embedding table and
              lm_head so the generator serves as a causal LM
              (`generator_lm_apply`) for the prefill/decode shapes.

  Discriminator  embedding sequence --in_proj--> backbone --mean-pool-->
              scalar real/fake logit. Real token data enters through the
              discriminator's own embedding table (feature-space GAN —
              the standard differentiable formulation for token data).

Conditioned families (whisper audio frames, llama-vision image patches)
pass the stub frontend embeddings as `enc_h` to both nets.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import nn
from repro.nn import initializers
from repro.configs.base import ArchConfig
from repro.models.backbone import (
    backbone_init, backbone_apply, encoder_init, encoder_apply)


def disc_config(cfg: ArchConfig) -> ArchConfig:
    if cfg.disc_layers is None:
        return cfg
    return dataclasses.replace(cfg, n_layers=cfg.disc_layers, disc_layers=None)


# ---------------------------------------------------------------------------
# Generator
# ---------------------------------------------------------------------------

def generator_init(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    params = {
        "z_proj": initializers.lecun_normal(ks[0], (cfg.d_z, cfg.d_model)),
        "backbone": backbone_init(ks[1], cfg),
        "out_proj": initializers.lecun_normal(ks[2], (cfg.d_model, cfg.d_model)),
        "embed": nn.embedding_init(ks[3], cfg.vocab, cfg.d_model),
        "lm_head": initializers.lecun_normal(ks[4], (cfg.d_model, cfg.vocab)),
    }
    if cfg.family == "encdec":
        params["encoder"] = encoder_init(ks[5], cfg)
    return params


def generator_apply(params, cfg: ArchConfig, z, *, enc_feats=None,
                    remat: bool = True, act_spec=None, tp_axis=None):
    """GAN mode: noise sequence -> synthetic embedding sequence (b, s, d).

    tp_axis: run the backbone's feed-forward blocks Megatron-style over
    a manual mesh axis (`params` hold the model-axis shards; the
    projections here stay replicated). See backbone_apply.
    """
    h = z @ params["z_proj"].astype(z.dtype)
    enc_h = _encode(params, cfg, enc_feats, remat=remat)
    out = backbone_apply(params["backbone"], cfg, h, mode="train",
                         enc_h=enc_h, remat=remat, act_spec=act_spec,
                         tp_axis=tp_axis)
    fake = out["h"] @ params["out_proj"].astype(h.dtype)
    return fake, out["aux"]


def generator_lm_init(key, cfg: ArchConfig):
    return generator_init(key, cfg)


def generator_lm_apply(params, cfg: ArchConfig, tokens, *, mode: str = "train",
                       caches=None, cache_index=None, positions=None,
                       cache_write_mask=None, paged_table=None,
                       enc_feats=None, remat: bool = True,
                       prefill_cache_len=None, tp_axis=None):
    """LM mode: tokens -> logits. Used by serving (prefill/decode) and
    by the LM-pretraining example.

    positions/cache_write_mask/paged_table: serving decode conventions
    (any-position batched decode, chunked prefill, paged caches) — see
    backbone_apply. tp_axis: Megatron feed-forward inside a shard_map
    slice (train-to-serve: same sharded-leaf contract as training)."""
    h = nn.embedding_apply(params["embed"], tokens)
    # decode attends cross-attention through the prefilled cache; the
    # encoder only runs on train/prefill.
    enc_h = None if mode == "decode" else _encode(params, cfg, enc_feats,
                                                  remat=remat)
    out = backbone_apply(params["backbone"], cfg, h, mode=mode,
                         caches=caches, cache_index=cache_index,
                         positions=positions, enc_h=enc_h, remat=remat,
                         prefill_cache_len=prefill_cache_len,
                         cache_write_mask=cache_write_mask,
                         paged_table=paged_table, tp_axis=tp_axis)
    logits = out["h"] @ params["lm_head"].astype(out["h"].dtype)
    return {"logits": logits, "aux": out["aux"], "caches": out["caches"]}


def _encode(params, cfg: ArchConfig, enc_feats, *, remat: bool):
    """Resolve cross-attention context from stub frontend features."""
    if cfg.family == "encdec":
        assert enc_feats is not None, f"{cfg.name} needs encoder features"
        return encoder_apply(params["encoder"], cfg, enc_feats, remat=remat)
    if cfg.family == "vlm":
        assert enc_feats is not None, f"{cfg.name} needs image embeddings"
        return enc_feats  # projector is part of the stub frontend
    return None


# ---------------------------------------------------------------------------
# Discriminator
# ---------------------------------------------------------------------------

def discriminator_init(key, cfg: ArchConfig):
    dcfg = disc_config(cfg)
    ks = jax.random.split(key, 5)
    params = {
        "in_proj": initializers.lecun_normal(ks[0], (cfg.d_model, cfg.d_model)),
        "backbone": backbone_init(ks[1], dcfg),
        "embed": nn.embedding_init(ks[2], cfg.vocab, cfg.d_model),
        "score": initializers.lecun_normal(ks[3], (cfg.d_model, 1)),
    }
    if cfg.family == "encdec":
        params["encoder"] = encoder_init(ks[4], dcfg)
    return params


def discriminator_embed(params, tokens):
    """Embed real token data into the discriminator's input space."""
    return nn.embedding_apply(params["embed"], tokens)


def discriminator_apply(params, cfg: ArchConfig, x_embed, *, enc_feats=None,
                        remat: bool = True, act_spec=None, tp_axis=None):
    """x_embed: (b, s, d) — real (embedded tokens) or fake (generator out).
    Returns per-example logits (b,). tp_axis as in generator_apply."""
    dcfg = disc_config(cfg)
    h = x_embed @ params["in_proj"].astype(x_embed.dtype)
    enc_h = _encode(params, dcfg, enc_feats, remat=remat)
    out = backbone_apply(params["backbone"], dcfg, h, mode="train",
                         enc_h=enc_h, remat=remat, act_spec=act_spec,
                         tp_axis=tp_axis)
    pooled = jnp.mean(out["h"].astype(jnp.float32), axis=1)
    logit = pooled @ params["score"].astype(jnp.float32)
    return logit[..., 0], out["aux"]


def gan_init(key, cfg: ArchConfig):
    kg, kd = jax.random.split(key)
    return {"gen": generator_init(kg, cfg), "disc": discriminator_init(kd, cfg)}


# ---------------------------------------------------------------------------
# Minimal MLP-GAN — the TP reference model
# ---------------------------------------------------------------------------

def mlp_gan_init(key, *, d_z: int = 8, d_hidden: int = 16, d_data: int = 64,
                 w_scale: float = 0.1):
    """Two-layer MLP G and D over flattened vectors, with the
    column/row-parallel leaf names (`w_in`/`w_out` — sharding.rules
    tp_leaf_dim) so the SAME parameter tree runs unsharded (tp=1, the
    host oracle) or Megatron-sharded inside a mesh slice. This is the
    dispatch-bound model `benchmarks/driver_bench.py` measures and the
    model the TP equivalence matrix pins."""
    ks = jax.random.split(key, 4)
    s = lambda k, sh: jax.random.normal(k, sh) * w_scale
    return {"gen": {"w_in": s(ks[0], (d_z, d_hidden)),
                    "w_out": s(ks[1], (d_hidden, d_data))},
            "disc": {"w_in": s(ks[2], (d_data, d_hidden)),
                     "w_out": s(ks[3], (d_hidden, 1))}}


def mlp_gan_spec(*, d_z: int = 8, tp_axis=None):
    """GanModelSpec for the MLP-GAN (see `core.protocol.GanModelSpec`).

    tp_axis=None is the plain dense math (any layout, any driver). With
    tp_axis set the spec must run inside shard_map with that axis live:
    w_in is column-parallel (copy_to_tp pins the backward dx psum),
    w_out row-parallel (one forward psum), for both networks — the
    Megatron pattern over shards the engine's state specs carve out.
    """
    from repro.core.protocol import GanModelSpec
    from repro.nn.linear import linear_apply

    def gen_apply(p, z):
        h = jnp.tanh(linear_apply({"w": p["w_in"]}, z, tp_axis=tp_axis,
                                  tp_mode="column"))
        return jnp.tanh(linear_apply({"w": p["w_out"]}, h, tp_axis=tp_axis,
                                     tp_mode="row"))

    def disc_logits(p, x):
        x = x.reshape(x.shape[0], -1)
        h = jnp.tanh(linear_apply({"w": p["w_in"]}, x, tp_axis=tp_axis,
                                  tp_mode="column"))
        return linear_apply({"w": p["w_out"]}, h, tp_axis=tp_axis,
                            tp_mode="row")[:, 0]

    return GanModelSpec(
        sample_z=lambda key, n: jax.random.normal(key, (n, d_z)),
        gen_apply=gen_apply, disc_real=disc_logits,
        disc_fake=disc_logits, tp_axis=tp_axis)

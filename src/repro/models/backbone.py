"""Unified grouped-scan backbone covering all assigned architectures.

A backbone is a repeated *group* of sublayers (`cfg.group_pattern`),
scanned `cfg.n_groups_stack` times with parameters stacked on a leading
group axis. This keeps the lowered HLO compact (one group body
regardless of depth) for every family:

  dense        group = (attn,)
  gemma3       group = (attn_local x5, attn_global)
  moe          group = (attn,)            with MoE feed-forward
  ssm          group = (ssm,)
  hybrid       group = (ssm x6, shared_attn)   [shared params, per-call cache]
  encdec       group = (attn, cross)      decoder; separate encoder stack
  vlm          group = (attn x4, cross)   gated cross-attn to image embeds

Modes: "train" (full seq, no cache), "prefill" (full seq, emits decode
caches), "decode" (one token against caches).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.nn.norms import rmsnorm_init
from repro.configs.base import ArchConfig
from repro.models import blocks


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _sublayer_init(key, cfg: ArchConfig, kind: str):
    if kind in ("attn", "attn_local", "attn_global"):
        return blocks.attn_layer_init(key, cfg)
    if kind == "cross":
        return blocks.cross_layer_init(key, cfg, gated=cfg.family == "vlm")
    if kind == "ssm":
        return blocks.ssm_layer_init(key, cfg)
    if kind == "shared_attn":
        return {}  # parameters live in the shared slot, not per group
    raise ValueError(kind)


def backbone_init(key, cfg: ArchConfig):
    pattern = cfg.group_pattern
    n_groups = cfg.n_groups_stack
    k_groups, k_shared, k_final = jax.random.split(key, 3)

    def one_group(gkey):
        sub_keys = jax.random.split(gkey, len(pattern))
        return {f"sub{i}": _sublayer_init(sub_keys[i], cfg, kind)
                for i, kind in enumerate(pattern)}

    group_keys = jax.random.split(k_groups, n_groups)
    params = {"groups": jax.vmap(one_group)(group_keys),
              "final_norm": blocks._norm_init(cfg, cfg.d_model)}
    if "shared_attn" in pattern:
        params["shared"] = blocks.attn_layer_init(k_shared, cfg)
    return params


def encoder_init(key, cfg: ArchConfig):
    """Bidirectional encoder stack (whisper). Input: precomputed frame
    embeddings (the conv/mel frontend is the assignment's stub)."""
    enc_cfg = dataclasses.replace(cfg, moe=None)
    keys = jax.random.split(key, cfg.n_enc_layers + 1)

    def one_layer(k):
        return blocks.attn_layer_init(k, enc_cfg, causal=False)

    return {"layers": jax.vmap(one_layer)(keys[:-1]),
            "final_norm": blocks._norm_init(cfg, cfg.d_model)}


def count_params(params) -> int:
    return int(sum(x.size for x in jax.tree_util.tree_leaves(params)))


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def _attn_cache(cfg: ArchConfig, batch: int, length: int, dtype):
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype=dtype),
        "pos": jnp.zeros((batch, length), dtype=jnp.int32),
        "valid": jnp.zeros((batch, length), dtype=bool),
    }


def _ssm_cache(cfg: ArchConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return {
        "ssm": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), dtype=jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype=dtype),
    }


def _cross_cache(cfg: ArchConfig, batch: int, dtype):
    t = cfg.enc_seq if cfg.family == "encdec" else cfg.n_image_tokens
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, t, cfg.n_kv_heads, hd), dtype=dtype),
        "v": jnp.zeros((batch, t, cfg.n_kv_heads, hd), dtype=dtype),
    }


def sublayer_cache_shape(cfg: ArchConfig, kind: str, batch: int,
                         cache_len: int, dtype):
    if kind in ("attn", "attn_local", "attn_global", "shared_attn"):
        window = cfg.sublayer_window(kind)
        length = cache_len if window is None else min(window, cache_len)
        return _attn_cache(cfg, batch, length, dtype)
    if kind == "ssm":
        return _ssm_cache(cfg, batch, dtype)
    if kind == "cross":
        return _cross_cache(cfg, batch, dtype)
    raise ValueError(kind)


def init_decode_caches(cfg: ArchConfig, batch: int, cache_len: int,
                       dtype=jnp.bfloat16):
    """Zeroed decode caches, stacked over the group axis (scan xs)."""
    pattern = cfg.group_pattern
    n_groups = cfg.n_groups_stack

    def stack(tree):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), tree)

    return {f"sub{i}": stack(sublayer_cache_shape(cfg, kind, batch, cache_len, dtype))
            for i, kind in enumerate(pattern)}


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------

def _kv_to_cache(kv, positions, window, cache_len: int):
    """Turn full-sequence k/v (b, s, kv_heads, hd) into a decode cache.

    Full attention: pad/place the s entries at slots [0, s) of a
    cache_len-sized buffer. Sliding window: keep the last `window`
    entries, scattered at their ring-buffer slots (pos % window) so a
    later decode insert at `pos % window` stays consistent.
    """
    k, v = kv["k"], kv["v"]
    b, s = k.shape[0], k.shape[1]
    if window is None or window >= cache_len:
        length = cache_len
        pad = length - s
        assert pad >= 0, f"prefill length {s} exceeds cache {length}"
        padk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        padv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.pad(positions, ((0, 0), (0, pad)))
        valid = jnp.pad(jnp.ones((b, s), dtype=bool), ((0, 0), (0, pad)))
        return {"k": padk, "v": padv, "pos": pos.astype(jnp.int32), "valid": valid}
    # ring buffer: slot j holds the latest position p <= s-1 with p % window == j
    import numpy as np
    j = np.arange(window)
    src = j + window * ((s - 1 - j) // window)     # in [s-window, s)
    src = np.clip(src, 0, s - 1)
    filled = src >= max(0, s - window)
    take = jnp.asarray(src)
    return {
        "k": jnp.take(k, take, axis=1),
        "v": jnp.take(v, take, axis=1),
        "pos": jnp.take(positions, take, axis=1).astype(jnp.int32),
        "valid": jnp.broadcast_to(jnp.asarray(filled), (b, window)),
    }


def _run_sublayer(params_i, cfg: ArchConfig, kind: str, h, *, inv_freq,
                  positions, cache, cache_index, enc_h, shared_params,
                  mode: str, cache_len: int = 0, ssd_scan_impl=None,
                  cache_write_mask=None, paged_table=None, tp_axis=None):
    """Dispatch one sublayer. Returns (h, aux, new_cache_or_None)."""
    if kind in ("attn", "attn_local", "attn_global", "shared_attn"):
        p = shared_params if kind == "shared_attn" else params_i
        window = cfg.sublayer_window(kind)
        dropless = mode != "train"   # serving never capacity-drops
        if mode == "decode":
            # only full-attention sublayers page (a sliding window is
            # already a bounded per-slot ring buffer)
            pt = paged_table if window is None else None
            return blocks.attn_layer_apply(
                p, cfg, h, window=window, inv_freq=inv_freq,
                positions=positions, cache=cache, cache_index=cache_index,
                cache_write_mask=cache_write_mask, paged_table=pt,
                moe_dropless=dropless, tp_axis=tp_axis)
        h, aux, kv = blocks.attn_layer_apply(
            p, cfg, h, window=window, inv_freq=inv_freq, positions=positions,
            return_kv=(mode == "prefill"), moe_dropless=dropless,
            tp_axis=tp_axis)
        new_cache = None
        if mode == "prefill":
            new_cache = _kv_to_cache(kv, positions, window, cache_len)
        return h, aux, new_cache
    if kind == "ssm":
        if mode == "decode":
            return blocks.ssm_layer_apply(params_i, cfg, h, state=cache,
                                          token_mask=cache_write_mask)
        return blocks.ssm_layer_apply(params_i, cfg, h,
                                      scan_impl=ssd_scan_impl,
                                      return_state=(mode == "prefill"))
    if kind == "cross":
        gated = cfg.family == "vlm"
        if mode == "decode":
            h, aux, _ = blocks.cross_layer_apply(
                params_i, cfg, h, enc_kv=cache, gated=gated,
                tp_axis=tp_axis)
            return h, aux, cache
        h, aux, kv = blocks.cross_layer_apply(
            params_i, cfg, h, enc_h=enc_h, gated=gated, tp_axis=tp_axis)
        return h, aux, (kv if mode == "prefill" else None)
    raise ValueError(kind)


def backbone_apply(params, cfg: ArchConfig, h, *, mode: str = "train",
                   caches=None, cache_index=None, positions=None,
                   enc_h=None, remat: bool = True, ssd_scan_impl=None,
                   prefill_cache_len: Optional[int] = None, act_spec=None,
                   cache_write_mask=None, paged_table=None, tp_axis=None):
    """Run the backbone.

    h: (b, s, d) hidden states (already embedded / projected).
    mode: "train" | "prefill" | "decode".
    caches/cache_index: decode state (see init_decode_caches). Serving
        passes cache_index=None with explicit per-token `positions`
        (b, s) — every cache insert then lands at its own absolute
        position (any-position batched decode / chunked prefill).
    cache_write_mask: (b, s) bool — tokens whose cache/state writes are
        exact no-ops (inactive serving slots, padded chunk tails).
    paged_table: (b, max_blocks) int32 block tables; full-attention
        caches are then shared block pools (see serving.cache).
    enc_h: encoder or image embeddings for cross sublayers.
    tp_axis: Megatron tensor parallelism of the dense feed-forward
        blocks over a manual (shard_map) mesh axis — `params` then hold
        the model-axis SHARDS of w_in/w_gate/w_out (sharding.rules
        tp_leaf_dim); attention/norms/embeds/ssm/moe replicate.
    Returns dict(h=..., aux=..., caches=...).
    """
    pattern = cfg.group_pattern
    b, s, _ = h.shape
    inv_freq = nn.rope_frequencies(cfg.resolved_head_dim, base=cfg.rope_base)
    if positions is None:
        if mode == "decode":
            assert cache_index is not None
            positions = jnp.full((b, s), cache_index, dtype=jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    shared_params = params.get("shared")
    cache_len = prefill_cache_len if prefill_cache_len is not None else s

    def constrain(x):
        # Sequence-parallel residual storage (Megatron-SP adaptation): the
        # scan carry is what remat keeps live across groups — pinning its
        # sharding caps per-chip activation memory at depth x (b, s/axes, d).
        if act_spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, act_spec)

    h = constrain(h)

    def group_body(carry, xs):
        h, aux = carry
        if mode == "decode":
            params_g, caches_g = xs
        else:
            params_g, caches_g = xs, None
        new_caches = {}
        for i, kind in enumerate(pattern):
            cache_i = caches_g[f"sub{i}"] if caches_g is not None else None
            h, aux_i, new_cache_i = _run_sublayer(
                params_g[f"sub{i}"], cfg, kind, h, inv_freq=inv_freq,
                positions=positions, cache=cache_i, cache_index=cache_index,
                enc_h=enc_h, shared_params=shared_params, mode=mode,
                cache_len=cache_len, ssd_scan_impl=ssd_scan_impl,
                cache_write_mask=cache_write_mask, paged_table=paged_table,
                tp_axis=tp_axis)
            aux = aux + aux_i
            if new_cache_i is not None:
                new_caches[f"sub{i}"] = new_cache_i
        return (constrain(h), aux), new_caches

    body = group_body
    if mode == "train" and remat:
        body = jax.checkpoint(group_body)

    aux0 = jnp.zeros((), dtype=jnp.float32)
    if mode == "decode":
        xs = (params["groups"], caches)
    else:
        xs = params["groups"]
    (h, aux), caches_out = jax.lax.scan(body, (h, aux0), xs)

    h = blocks._norm_apply(cfg, params["final_norm"], h)
    return {"h": h, "aux": aux, "caches": caches_out if caches_out else None}


def cross_decode_kv(params, cfg: ArchConfig, enc_h):
    """Project encoder/image states through every cross sublayer's k/v.

    Returns {"subI": {"k": (G, b, t, kv, hd), "v": ...}} so a serving
    engine can populate per-slot cross caches at admission (decode then
    runs kv_override against them) without a full prefill pass.
    """
    out = {}
    for i, kind in enumerate(cfg.group_pattern):
        if kind != "cross":
            continue
        attn_p = params["groups"][f"sub{i}"]["attn"]
        out[f"sub{i}"] = jax.vmap(
            lambda p: nn.attention_kv(p, enc_h, n_kv_heads=cfg.n_kv_heads,
                                      qk_norm=cfg.qk_norm))(attn_p)
    return out


def encoder_apply(params, cfg: ArchConfig, feats, *, remat: bool = True):
    """Bidirectional encoder over stub frame embeddings (b, t, d)."""
    b, t, _ = feats.shape
    inv_freq = nn.rope_frequencies(cfg.resolved_head_dim, base=cfg.rope_base)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def layer_body(carry, layer_params):
        h, = carry
        h, _, _ = blocks.attn_layer_apply(
            layer_params, cfg, h, window=None, inv_freq=inv_freq,
            positions=positions, causal=False)
        return (h,), None

    body = jax.checkpoint(layer_body) if remat else layer_body
    (h,), _ = jax.lax.scan(body, (feats,), params["layers"])
    return blocks._norm_apply(cfg, params["final_norm"], h)

"""GanModelSpec adapters: plug concrete models into the protocol."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.configs.dcgan import DCGANConfig
from repro.core.protocol import GanModelSpec
from repro.models import dcgan as dcgan_model
from repro.models import gan as gan_model


def make_dcgan_spec(cfg: DCGANConfig, *,
                    gen_loss_variant: str = "minimax") -> GanModelSpec:
    """The paper's experimental model: image GAN over (b, H, W, C)."""
    return GanModelSpec(
        sample_z=lambda key, n: jax.random.normal(key, (n, cfg.nz)),
        gen_apply=lambda gen, z: dcgan_model.generator_apply(gen, cfg, z),
        disc_real=lambda disc, x: dcgan_model.discriminator_apply(disc, cfg, x),
        disc_fake=lambda disc, f: dcgan_model.discriminator_apply(disc, cfg, f),
        gen_loss_variant=gen_loss_variant,
    )


def make_backbone_spec(cfg: ArchConfig, seq_len: int, *,
                       enc_feats_fn=None, remat: bool = True,
                       gen_loss_variant: str = "minimax",
                       act_spec_gen=None, act_spec_disc=None,
                       dtype=jnp.float32, tp_axis=None) -> GanModelSpec:
    """Backbone-GAN over token data.

    Real batches are token arrays (m, seq_len); they enter the
    discriminator through its embedding table. Fakes are generator
    embedding sequences (m, seq_len, d). Conditioned families get their
    stub frontend features from enc_feats_fn(n) (deterministic stub).

    tp_axis: Megatron tensor parallelism of BOTH nets' feed-forward
    blocks over a manual (shard_map) mesh axis — the params passed to
    the apply functions must then be the model-axis shards
    (sharding.rules tp_leaf_dim names). Mutually exclusive with the
    GSPMD act specs (those constrain a global program; tp_axis is the
    explicit-collective slice program). fuse_proj configs cannot TP
    (the fused [in|gate] halves don't shard contiguously).
    """
    if tp_axis is not None:
        assert act_spec_gen is None and act_spec_disc is None, \
            "tp_axis is the shard_map path; GSPMD act specs don't apply"
        if cfg.fuse_proj:
            raise ValueError(
                f"{cfg.name}: fuse_proj=True cannot be tensor-parallel "
                f"(fused [in|gate] halves don't shard contiguously); "
                f"use a non-fused config for tp > 1")
        if cfg.moe is not None:
            raise ValueError(
                f"{cfg.name}: MoE feed-forward has no in-slice TP path "
                f"yet (moe_apply runs dense per expert; expert "
                f"parallelism is a ROADMAP item) — use tp=1 for MoE "
                f"configs on the mesh layout")

    def enc(n):
        return enc_feats_fn(n) if enc_feats_fn is not None else None

    def sample_z(key, n):
        # dtype matters: f32 noise would promote every downstream matmul
        # (and all remat-carried residuals) to f32.
        return jax.random.normal(key, (n, seq_len, cfg.d_z), dtype=dtype)

    def gen_apply(gen, z):
        fake, _aux = gan_model.generator_apply(gen, cfg, z,
                                               enc_feats=enc(z.shape[0]),
                                               remat=remat,
                                               act_spec=act_spec_gen,
                                               tp_axis=tp_axis)
        return fake

    def disc_real(disc, tokens):
        x = gan_model.discriminator_embed(disc, tokens)
        logits, _aux = gan_model.discriminator_apply(
            disc, cfg, x, enc_feats=enc(tokens.shape[0]), remat=remat,
            act_spec=act_spec_disc, tp_axis=tp_axis)
        return logits

    def disc_fake(disc, fake):
        logits, _aux = gan_model.discriminator_apply(
            disc, cfg, fake, enc_feats=enc(fake.shape[0]), remat=remat,
            act_spec=act_spec_disc, tp_axis=tp_axis)
        return logits

    return GanModelSpec(sample_z=sample_z, gen_apply=gen_apply,
                        disc_real=disc_real, disc_fake=disc_fake,
                        gen_loss_variant=gen_loss_variant,
                        tp_axis=tp_axis)


def make_stub_enc_feats(cfg: ArchConfig, *, seed: int = 7):
    """Deterministic stand-in for the stubbed modality frontend
    (mel+conv for whisper, ViT+projector for llama-vision)."""
    if cfg.family == "encdec":
        t = cfg.enc_seq
    elif cfg.family == "vlm":
        t = cfg.n_image_tokens
    else:
        return None
    base = jax.random.normal(jax.random.PRNGKey(seed), (1, t, cfg.d_model))

    def enc_feats(n):
        return jnp.broadcast_to(base, (n, t, cfg.d_model))

    return enc_feats

"""Sublayer blocks composed by the grouped-scan backbone.

Each block is (init, apply) over a full residual sublayer. `apply`
uniformly takes/returns an optional cache dict so the backbone can treat
train / prefill / decode with one code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import nn
from repro.nn.norms import rmsnorm_init, rmsnorm_apply, layernorm_init, layernorm_apply
from repro.configs.base import ArchConfig


def _norm_init(cfg: ArchConfig, d: int):
    if cfg.use_attn_bias:  # whisper flavour -> LayerNorm
        return layernorm_init(d)
    return rmsnorm_init(d)


def _norm_apply(cfg: ArchConfig, params, x):
    if cfg.use_attn_bias:
        return layernorm_apply(params, x)
    return rmsnorm_apply(params, x, eps=cfg.norm_eps)


# ---------------------------------------------------------------------------
# Self-attention + FF layer (dense or MoE)
# ---------------------------------------------------------------------------

def attn_layer_init(key, cfg: ArchConfig, *, causal: bool = True):
    ka, kf = jax.random.split(key)
    params = {
        "ln_attn": _norm_init(cfg, cfg.d_model),
        "attn": nn.attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            qk_norm=cfg.qk_norm, use_bias=cfg.use_attn_bias,
            fuse_qkv=cfg.fuse_proj),
        "ln_ff": _norm_init(cfg, cfg.d_model),
    }
    if cfg.moe is not None:
        params["ff"] = nn.moe_init(kf, cfg.d_model, cfg.moe.d_ff_expert,
                                   cfg.moe.n_experts)
    else:
        params["ff"] = nn.mlp_init(kf, cfg.d_model, cfg.d_ff,
                                   gated=not cfg.use_attn_bias,
                                   use_bias=cfg.use_attn_bias,
                                   fuse_gate=cfg.fuse_proj)
    return params


def attn_layer_apply(params, cfg: ArchConfig, h, *, window: Optional[int],
                     inv_freq, positions, causal: bool = True,
                     cache=None, cache_index=None, cache_write_mask=None,
                     paged_table=None, return_kv: bool = False,
                     moe_dropless: bool = False, tp_axis=None):
    """Returns (h, aux_loss, new_cache_or_kv). tp_axis runs the dense
    feed-forward Megatron-style inside a shard_map slice (attention and
    MoE replicate over the model axis). cache_write_mask / paged_table
    select the serving scatter/paged cache paths (see attention_apply)."""
    x = _norm_apply(cfg, params["ln_attn"], h)
    out = nn.attention_apply(
        params["attn"], x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        inv_freq=inv_freq, q_positions=positions, causal=causal,
        window=window, qk_norm=cfg.qk_norm,
        cache=cache, cache_index=cache_index,
        cache_write_mask=cache_write_mask, paged_table=paged_table,
        return_kv=return_kv,
        flash_repeat_kv=cfg.flash_repeat_kv)
    if cache is not None or return_kv:
        attn_out, new_cache = out
    else:
        attn_out, new_cache = out, None
    h = h + attn_out
    x = _norm_apply(cfg, params["ln_ff"], h)
    aux = jnp.zeros((), dtype=jnp.float32)
    if cfg.moe is not None:
        ff_out, aux = nn.moe_apply(
            params["ff"], x, n_experts=cfg.moe.n_experts, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor,
            group_size=cfg.moe.group_size, dispatch=cfg.moe.dispatch,
            dropless=moe_dropless)
    else:
        ff_out = nn.mlp_apply(params["ff"], x, tp_axis=tp_axis)
    h = h + ff_out
    return h, aux, new_cache


# ---------------------------------------------------------------------------
# Cross-attention layer (whisper decoder per-layer; llama-vision gated)
# ---------------------------------------------------------------------------

def cross_layer_init(key, cfg: ArchConfig, *, gated: bool):
    ka, kf = jax.random.split(key)
    params = {
        "ln": _norm_init(cfg, cfg.d_model),
        "attn": nn.attention_init(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim,
            qk_norm=cfg.qk_norm, use_bias=cfg.use_attn_bias),
    }
    if gated:
        # llama-3.2-vision style gated cross-attn with its own FF sublayer
        params["gate_attn"] = jnp.zeros(())
        params["gate_ff"] = jnp.zeros(())
        params["ln_ff"] = _norm_init(cfg, cfg.d_model)
        params["ff"] = nn.mlp_init(kf, cfg.d_model, cfg.d_ff, gated=True)
    return params


def cross_layer_apply(params, cfg: ArchConfig, h, *, enc_h=None,
                      enc_kv=None, gated: bool, tp_axis=None):
    """Cross-attend to encoder/image states.

    enc_h: (b, t, d) raw encoder states (train/prefill) — k/v projected here.
    enc_kv: pre-projected {"k","v"} cache (decode) — skips the projection.
    Returns (h, aux, enc_kv_out) where enc_kv_out is the projected k/v
    (so prefill can populate the cross cache once).
    """
    x = _norm_apply(cfg, params["ln"], h)
    if enc_kv is not None:
        attn_out = nn.attention_apply(
            params["attn"], x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            inv_freq=None, causal=False, qk_norm=cfg.qk_norm,
            kv_override=enc_kv)
        kv_out = enc_kv
    else:
        attn_out, kv_out = nn.attention_apply(
            params["attn"], x, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            inv_freq=None, causal=False, qk_norm=cfg.qk_norm,
            kv_x=enc_h, return_kv=True)
    if gated:
        attn_out = jnp.tanh(params["gate_attn"]).astype(h.dtype) * attn_out
    h = h + attn_out
    aux = jnp.zeros((), dtype=jnp.float32)
    if gated:
        x = _norm_apply(cfg, params["ln_ff"], h)
        ff_out = nn.mlp_apply(params["ff"], x, tp_axis=tp_axis)
        h = h + jnp.tanh(params["gate_ff"]).astype(h.dtype) * ff_out
    return h, aux, kv_out


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) layer
# ---------------------------------------------------------------------------

def ssm_layer_init(key, cfg: ArchConfig):
    s = cfg.ssm
    return {
        "ln": rmsnorm_init(cfg.d_model),
        "mixer": nn.ssd_mixer_init(
            key, cfg.d_model, d_state=s.d_state, head_dim=s.head_dim,
            expand=s.expand, n_groups=s.n_groups, d_conv=s.d_conv),
    }


def ssm_layer_apply(params, cfg: ArchConfig, h, *, state=None,
                    token_mask=None, scan_impl=None,
                    return_state: bool = False):
    """Returns (h, aux, new_state)."""
    s = cfg.ssm
    x = rmsnorm_apply(params["ln"], h, eps=cfg.norm_eps)
    out = nn.ssd_mixer_apply(
        params["mixer"], x, d_state=s.d_state, head_dim=s.head_dim,
        expand=s.expand, n_groups=s.n_groups, chunk=s.chunk,
        state=state, token_mask=token_mask, scan_impl=scan_impl,
        return_state=return_state)
    if state is not None or return_state:
        mixed, new_state = out
    else:
        mixed, new_state = out, None
    return h + mixed, jnp.zeros((), dtype=jnp.float32), new_state

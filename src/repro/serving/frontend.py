"""Async request front-end over ServingEngine.

The engine itself is a synchronous step loop; this wraps it in a driver
thread so callers submit prompts and get back `concurrent.futures.Future`
objects that resolve to the finished Request (or raise RuntimeError on
rejection). This is the closed-loop load-generator surface: the bench
submits at an offered arrival rate and awaits futures for latency.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.serving.engine import Request, ServingEngine


class ServingFrontend:
    """Thread-driving front-end: `submit` is safe from any thread; the
    engine only ever steps on the driver thread."""

    def __init__(self, engine: ServingEngine, *, idle_sleep: float = 0.001):
        self.engine = engine
        self.idle_sleep = idle_sleep
        self._lock = threading.Lock()
        self._inbox: deque = deque()
        self._futures: dict[int, Future] = {}
        self._rid = 0
        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def submit(self, prompt, max_new_tokens: int = 16,
               temperature: float = 0.0) -> Future:
        fut: Future = Future()
        with self._lock:
            rid = self._rid
            self._rid += 1
            req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens,
                          temperature=temperature)
            self._inbox.append(req)
            self._futures[rid] = fut
        return fut

    def _drain_inbox(self):
        with self._lock:
            reqs = list(self._inbox)
            self._inbox.clear()
        for req in reqs:
            self.engine.submit(req)

    def _resolve_done(self):
        done = []
        for lst, ok in ((self.engine.finished, True),
                        (self.engine.rejected, False)):
            for req in lst:
                fut = self._futures.pop(req.rid, None)
                if fut is None:
                    continue
                done.append((fut, req, ok))
        for fut, req, ok in done:
            if ok:
                fut.set_result(req)
            else:
                fut.set_exception(RuntimeError(f"rejected: {req.failed}"))

    def _loop(self):
        while self._running:
            self._drain_inbox()
            progressed = self.engine.step()
            self._resolve_done()
            if not progressed:
                time.sleep(self.idle_sleep)

    def close(self, timeout: Optional[float] = 10.0):
        self._running = False
        self._thread.join(timeout=timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

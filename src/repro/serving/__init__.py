from repro.serving.engine import ServingEngine, Request
from repro.serving.frontend import ServingFrontend
from repro.serving import cache

"""Batched serving engine for the trained generator-as-LM.

Slot-based continuous batching: a fixed decode batch of B slots; each
slot holds one request's KV/SSM state inside the shared cache pytree
(all caches are allocated once at engine construction — decode steps are
a single jitted call regardless of request mix). Prefill runs per
request (padded to the slot cache) and its caches are scattered into the
slot. Greedy or temperature sampling.

This is the runnable CPU-scale counterpart of the decode_32k /
long_500k dry-run shapes.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import gan
from repro.models.backbone import init_decode_caches


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ArchConfig, gen_params, *, batch_size: int = 4,
                 max_len: int = 256, enc_feats_fn: Optional[Callable] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.params = gen_params
        self.b = batch_size
        self.max_len = max_len
        self.enc_feats_fn = enc_feats_fn
        self.caches = init_decode_caches(cfg, batch_size, max_len,
                                         dtype=jnp.float32)
        self.positions = np.zeros(batch_size, dtype=np.int32)  # next index
        self.slots: list[Optional[Request]] = [None] * batch_size
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("plen",))

    # -- jitted bodies --------------------------------------------------
    def _prefill_impl(self, params, tokens, enc_feats, plen):
        out = gan.generator_lm_apply(
            params, self.cfg, tokens, mode="prefill", enc_feats=enc_feats,
            remat=False, prefill_cache_len=self.max_len)
        return out["logits"][:, plen - 1, :], out["caches"]

    def _decode_impl(self, params, caches, token, cache_index, enc_feats):
        out = gan.generator_lm_apply(
            params, self.cfg, token, mode="decode", caches=caches,
            cache_index=cache_index, enc_feats=enc_feats, remat=False)
        return out["logits"][:, 0, :], out["caches"]

    # -- host logic ------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _enc(self, n):
        return self.enc_feats_fn(n) if self.enc_feats_fn else None

    def _admit(self):
        for slot in range(self.b):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                plen = len(req.prompt)
                assert plen + req.max_new_tokens <= self.max_len
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                logits, pre_caches = self._prefill(self.params, toks,
                                                   self._enc(1), plen=plen)
                # scatter this request's prefill caches into its slot
                def place(cache_leaf, pre_leaf):
                    return cache_leaf.at[:, slot:slot + 1].set(
                        pre_leaf.astype(cache_leaf.dtype))
                self.caches = jax.tree.map(place, self.caches, pre_caches)
                self.positions[slot] = plen
                first = self._sample(logits[0], req)
                req.out_tokens.append(int(first))
                self.slots[slot] = req

    def _sample(self, logits, req: Request):
        if req.temperature <= 0:
            return jnp.argmax(logits)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / req.temperature)

    def step(self):
        """One engine iteration: admit waiting requests, run one decode
        step for every active slot, retire finished requests."""
        self._admit()
        active = [s for s in range(self.b) if self.slots[s] is not None]
        if not active:
            return False
        # batchwise decode: cache_index must be uniform per call — group
        # slots by position (simple implementation: run one group per
        # distinct position per step).
        positions = {self.positions[s] for s in active}
        pos = min(positions)
        group = [s for s in active if self.positions[s] == pos]
        token = np.zeros((self.b, 1), dtype=np.int32)
        for s in group:
            token[s, 0] = self.slots[s].out_tokens[-1]
        logits, new_caches = self._decode(self.params, self.caches,
                                          jnp.asarray(token),
                                          jnp.int32(pos), self._enc(self.b))
        # the decode call wrote slot `pos` for EVERY batch row; keep the
        # new caches only for the slots that actually decoded this step.
        in_group = jnp.asarray([s in group for s in range(self.b)])

        def merge(old, new):
            # cache leaves are (G, b, ...) — mask over the batch axis
            m = in_group.reshape((1, self.b) + (1,) * (old.ndim - 2))
            return jnp.where(m, new.astype(old.dtype), old)

        self.caches = jax.tree.map(merge, self.caches, new_caches)
        for s in group:
            req = self.slots[s]
            nxt = int(self._sample(logits[s], req))
            req.out_tokens.append(nxt)
            self.positions[s] = pos + 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.finished.append(req)
                self.slots[s] = None
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

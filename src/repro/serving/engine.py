"""Continuous-batching serving engine for the trained generator-as-LM.

One jitted step per engine iteration, covering the whole request mix:

  * any-position batched decode — the step takes a per-slot position
    VECTOR, so every active slot decodes every step regardless of where
    it is in its sequence (no per-position grouping, no head-of-line
    blocking), with greedy/temperature sampling fused on-device (the
    host reads back one small token array per step, never logits);
  * chunked prefill interleaved with decode — one prompt chunk (padded
    to a power-of-two bucket, so prefill compiles O(log max_len) times)
    runs through the SAME jitted call as the decode batch, against the
    same caches, using exact no-op masking for the padded tail;
  * paged KV cache (serving.cache) — full-attention caches are shared
    block pools addressed through per-slot block tables, so persistent
    memory scales with live tokens instead of batch x max_len;
  * optional tensor-parallel decode (tp > 1): the step body runs inside
    a shard_map over a (1, model=tp) mesh with `rules.tp_param_specs`
    in_specs — an unmodified GLOBAL-shaped training checkpoint shards
    on entry exactly as training shards it (train-to-serve), the MLP
    psums of `nn/tp.py` keep activations replicated, and sampling is
    computed identically on every rank.

Sampling streams are keyed by (seed, rid, token_index), so a request's
tokens are a deterministic function of the request alone — independent
of scheduling, batch composition, and paged-vs-dense backend.

Host-side: deque admission (FIFO by rid), a rejection path for requests
that can never fit (marked failed; the engine keeps running), and a
block allocator for the paged pool (pool exhaustion queues the head
rather than failing it).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import gan
from repro.models.backbone import (init_decode_caches, cross_decode_kv,
                                   encoder_apply)
from repro.serving import cache as paging
from repro.sharding import rules
from repro.launch.mesh import (make_host_mesh, shard_map_compat,
                               tp_mesh_error, devices_error)


@dataclasses.dataclass
class Request:
    rid: Optional[int]
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    failed: Optional[str] = None        # rejection reason (engine keeps going)


@dataclasses.dataclass
class _Slot:
    req: Request
    pos: int = 0                 # prompt cursor (prefill) / next write index
    blocks: list = dataclasses.field(default_factory=list)
    prefilled: bool = False


def _pow2_bucket(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _sample_one(key, logits, temp):
    """Greedy/temperature sampling fused on-device. temp <= 0 => argmax."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    sampled = jax.random.categorical(
        key, logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6))
    return jnp.where(temp > 0, sampled.astype(jnp.int32), greedy)


_DEC_FIELDS = ("tokens", "pos", "active", "temp", "rid", "nout")
_PF_FIELDS = ("tokens", "slot", "pos0", "nvalid", "rid", "temp")


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch of B
    slots. See module docstring. block_size=None serves from dense
    per-slot caches (the baseline); an int turns on the paged pool."""

    def __init__(self, cfg: ArchConfig, gen_params, *, batch_size: int = 4,
                 max_len: int = 256, block_size: Optional[int] = None,
                 n_blocks: Optional[int] = None, prefill_chunk: int = 32,
                 enc_feats_fn: Optional[Callable] = None, seed: int = 0,
                 tp: int = 1, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = gen_params
        self.b = batch_size
        self.max_len = max_len
        self.seed = seed
        self.enc_feats_fn = enc_feats_fn
        self.prefill_chunk = max(1, min(prefill_chunk, max_len))
        self.paged = block_size is not None
        self.tp = tp

        if tp > 1:
            if cfg.moe is not None:
                raise ValueError(
                    f"{cfg.name}: MoE serving is tp=1 only (expert "
                    f"parallelism is a ROADMAP item)")
            if cfg.fuse_proj:
                raise ValueError(
                    f"{cfg.name}: fuse_proj=True cannot be tensor-parallel "
                    f"(fused leaves have no per-shard name rule)")
            err = devices_error(tp, context=f"serving --tp {tp}")
            if err:
                raise RuntimeError(err)
            self._mesh = make_host_mesh(1, tp)
            err = tp_mesh_error(self._mesh, tp)
            if err:
                raise ValueError(err)
            self._pspecs = rules.tp_param_specs(gen_params, "model", tp)

        if self.paged:
            self.caches, meta = paging.init_paged_caches(
                cfg, batch_size, max_len, block_size=block_size,
                n_blocks=n_blocks, dtype=cache_dtype)
            self.block_size = meta["block_size"]
            self.n_blocks = meta["n_blocks"]
            self.max_blocks = meta["max_blocks"]
            self._paged_subs = frozenset(meta["paged_subs"])
            self.alloc = paging.BlockAllocator(self.n_blocks)
        else:
            self.caches = init_decode_caches(cfg, batch_size, max_len,
                                             dtype=cache_dtype)
            self.max_blocks = 1
            self._paged_subs = frozenset()
            self.alloc = None
        self.table = np.zeros((batch_size, self.max_blocks), dtype=np.int32)

        self._fill_cross_caches()
        self.slots: list[Optional[_Slot]] = [None] * batch_size
        self.queue: deque[Request] = deque()
        self.rejected: list[Request] = []
        self.finished: list[Request] = []
        self._pf_order: deque[int] = deque()   # slots awaiting prefill, FIFO
        self._next_rid = 0
        self._steps = {}                       # chunk bucket -> jitted step
        self.dispatch_count = 0                # jitted calls issued
        self._clear_fn = None
        self._reset_fn = None

    # -- construction helpers -------------------------------------------

    def _fill_cross_caches(self):
        """Populate per-slot cross-attention caches once: the stub
        frontend features are request-independent, so every slot shares
        the same projected encoder k/v."""
        if self.cfg.family not in ("encdec", "vlm"):
            return
        assert self.enc_feats_fn is not None, f"{self.cfg.name} needs enc feats"
        feats = self.enc_feats_fn(1)
        if self.cfg.family == "encdec":
            enc_h = jax.jit(
                lambda p, f: encoder_apply(p, self.cfg, f, remat=False)
            )(self.params["encoder"], feats)
        else:
            enc_h = feats
        kvs = jax.jit(
            lambda p, e: cross_decode_kv(p, self.cfg, e)
        )(self.params["backbone"], enc_h)
        for name, kv in kvs.items():
            tgt = self.caches[name]
            self.caches[name] = {
                leaf: jnp.broadcast_to(
                    kv[leaf][:, 0][:, None].astype(tgt[leaf].dtype),
                    tgt[leaf].shape).copy()
                for leaf in tgt}

    # -- the jitted step -------------------------------------------------

    def _split_slot_caches(self, caches, slot):
        """Views for a one-slot prefill: paged pools pass whole (they are
        slot-agnostic — the block table isolates slots), per-slot dense
        leaves are sliced to batch row `slot`."""
        def slice_sub(sub):
            return jax.tree.map(
                lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
                sub)
        return {name: (sub if name in self._paged_subs else slice_sub(sub))
                for name, sub in caches.items()}

    def _merge_slot_caches(self, caches, new_sub, slot):
        def merge(full, part):
            return jax.lax.dynamic_update_slice_in_dim(
                full, part.astype(full.dtype), slot, axis=1)
        return {name: (new_sub[name] if name in self._paged_subs
                       else jax.tree.map(merge, caches[name], new_sub[name]))
                for name in caches}

    def _build_step(self, chunk: Optional[int]):
        """One fused serving step: an optional prefill chunk for a single
        slot, then the any-position decode batch, then on-device
        sampling. chunk=None builds the decode-only variant."""
        cfg = self.cfg
        paged = self.paged
        tp_axis = "model" if self.tp > 1 else None

        def body(params, caches, table, seed, dec, pf=None):
            base = jax.random.PRNGKey(seed)
            pf_token = jnp.zeros((), dtype=jnp.int32)
            if chunk is not None:
                sl = pf["slot"]
                row = jax.lax.dynamic_slice_in_dim(table, sl, 1, axis=0)
                positions = (pf["pos0"]
                             + jnp.arange(chunk, dtype=jnp.int32))[None]
                mask = (jnp.arange(chunk, dtype=jnp.int32)
                        < pf["nvalid"])[None]
                out = gan.generator_lm_apply(
                    params, cfg, pf["tokens"], mode="decode",
                    caches=self._split_slot_caches(caches, sl),
                    positions=positions, cache_write_mask=mask,
                    paged_table=row if paged else None, remat=False,
                    tp_axis=tp_axis)
                caches = self._merge_slot_caches(caches, out["caches"], sl)
                last = jax.lax.dynamic_index_in_dim(
                    out["logits"][0], pf["nvalid"] - 1, axis=0,
                    keepdims=False)
                pf_key = jax.random.fold_in(
                    jax.random.fold_in(base, pf["rid"]), 0)
                pf_token = _sample_one(pf_key, last, pf["temp"])
            out = gan.generator_lm_apply(
                params, cfg, dec["tokens"], mode="decode", caches=caches,
                positions=dec["pos"][:, None],
                cache_write_mask=dec["active"][:, None],
                paged_table=jnp.asarray(table) if paged else None,
                remat=False, tp_axis=tp_axis)
            logits = out["logits"][:, 0]
            keys = jax.vmap(lambda r, n: jax.random.fold_in(
                jax.random.fold_in(base, r), n))(dec["rid"], dec["nout"])
            toks = jax.vmap(_sample_one)(keys, logits, dec["temp"])
            return out["caches"], toks, pf_token

        if self.tp > 1:
            rep = lambda tree: jax.tree.map(lambda _: P(), tree)
            in_specs = [self._pspecs, rep(self.caches), P(), P(),
                        {k: P() for k in _DEC_FIELDS}]
            if chunk is not None:
                in_specs.append({k: P() for k in _PF_FIELDS})
            body = shard_map_compat(
                body, mesh=self._mesh, in_specs=tuple(in_specs),
                out_specs=(rep(self.caches), P(), P()))
        return jax.jit(body, donate_argnums=(1,))

    def _get_step(self, chunk: Optional[int]):
        if chunk not in self._steps:
            self._steps[chunk] = self._build_step(chunk)
        return self._steps[chunk]

    @property
    def compile_count(self) -> int:
        """Distinct (prefill-bucket) step programs built so far — bounded
        by 1 + log2(prefill_chunk) + 1 regardless of prompt mix."""
        return len(self._steps)

    def cache_bytes(self) -> int:
        return paging.cache_bytes(self.caches)

    # -- host logic ------------------------------------------------------

    def submit(self, req: Request):
        if req.rid is None:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid) + 1
        self.queue.append(req)

    def _reject(self, req: Request, reason: str):
        req.failed = reason
        self.rejected.append(req)

    def _admit(self):
        """FIFO admission (deque order == rid order): validation failures
        are rejected and skipped; a head that merely can't fit RIGHT NOW
        (no free slot / pool exhausted) blocks the queue — later
        requests never overtake it."""
        while self.queue:
            req = self.queue[0]
            plen = len(req.prompt)
            total = plen + req.max_new_tokens
            if plen == 0:
                self.queue.popleft()
                self._reject(req, "empty prompt")
                continue
            if total > self.max_len:
                self.queue.popleft()
                self._reject(
                    req, f"needs {total} tokens > engine max_len "
                         f"{self.max_len}")
                continue
            slot = next((s for s in range(self.b) if self.slots[s] is None),
                        None)
            if slot is None:
                return
            blocks = []
            if self.paged:
                need = -(-total // self.block_size)
                blocks = self.alloc.alloc(need)
                if blocks is None:
                    return          # pool exhausted: head waits, FIFO holds
            self.queue.popleft()
            self.table[slot, :] = 0
            if blocks:
                self.table[slot, :len(blocks)] = blocks
            self.caches = self._reset_slot(self.caches, slot)
            self.slots[slot] = _Slot(req=req, pos=0, blocks=blocks)
            self._pf_order.append(slot)

    def _reset_slot(self, caches, slot: int):
        """Wipe the per-slot dense state a previous occupant left behind:
        SSM/conv carries zero, attention ring/cache valid bits drop.
        (Paged pools need no reset — the fresh block table isolates the
        slot, and retired blocks are invalidated on free. Cross caches
        hold the shared encoder k/v and must persist.)"""
        if self._reset_fn is None:
            paged_subs = self._paged_subs

            def reset(caches, slot):
                def reset_sub(sub):
                    out = {}
                    for leaf, l in sub.items():
                        if leaf == "valid":
                            out[leaf] = l.at[:, slot].set(False)
                        elif leaf in ("ssm", "conv"):
                            out[leaf] = l.at[:, slot].set(0)
                        else:
                            out[leaf] = l
                    return out
                return {name: (sub if name in paged_subs
                               else reset_sub(sub))
                        for name, sub in caches.items()}

            self._reset_fn = jax.jit(reset)
        return self._reset_fn(caches, np.int32(slot))

    def _retire(self, slot: int):
        sl = self.slots[slot]
        sl.req.done = True
        self.finished.append(sl.req)
        if self.paged and sl.blocks:
            ids = np.zeros((self.max_blocks,), dtype=np.int32)
            ids[:len(sl.blocks)] = sl.blocks
            if self._clear_fn is None:
                subs = self._paged_subs
                self._clear_fn = jax.jit(
                    lambda c, i: paging.invalidate_blocks(c, sorted(subs), i))
            self.caches = self._clear_fn(self.caches, jnp.asarray(ids))
            self.alloc.free(sl.blocks)
        self.table[slot, :] = 0
        self.slots[slot] = None

    def _next_prefill(self):
        """The oldest admitted slot still prefilling, with its next chunk
        (bucketed to a power of two <= prefill_chunk)."""
        while self._pf_order and (
                self.slots[self._pf_order[0]] is None
                or self.slots[self._pf_order[0]].prefilled):
            self._pf_order.popleft()
        if not self._pf_order:
            return None
        slot = self._pf_order[0]
        sl = self.slots[slot]
        plen = len(sl.req.prompt)
        remaining = plen - sl.pos
        bucket = (self.prefill_chunk if remaining >= self.prefill_chunk
                  else _pow2_bucket(remaining))
        nvalid = min(remaining, bucket)
        tokens = np.zeros((1, bucket), dtype=np.int32)
        tokens[0, :nvalid] = sl.req.prompt[sl.pos:sl.pos + nvalid]
        pf = {"tokens": tokens, "slot": np.int32(slot),
              "pos0": np.int32(sl.pos), "nvalid": np.int32(nvalid),
              "rid": np.int32(sl.req.rid),
              "temp": np.float32(sl.req.temperature)}
        return slot, pf, bucket, nvalid

    def step(self) -> bool:
        """One engine iteration: admit, run ONE jitted call covering the
        next prefill chunk (if any) + every active decode slot, retire
        finished requests. Returns whether any work ran."""
        self._admit()
        pf_work = self._next_prefill()
        dec_slots = [s for s in range(self.b)
                     if self.slots[s] is not None and self.slots[s].prefilled]
        if pf_work is None and not dec_slots:
            return False

        dec = {"tokens": np.zeros((self.b, 1), dtype=np.int32),
               "pos": np.zeros((self.b,), dtype=np.int32),
               "active": np.zeros((self.b,), dtype=bool),
               "temp": np.zeros((self.b,), dtype=np.float32),
               "rid": np.zeros((self.b,), dtype=np.int32),
               "nout": np.zeros((self.b,), dtype=np.int32)}
        for s in dec_slots:
            sl = self.slots[s]
            dec["tokens"][s, 0] = sl.req.out_tokens[-1]
            dec["pos"][s] = sl.pos
            dec["active"][s] = True
            dec["temp"][s] = sl.req.temperature
            dec["rid"][s] = sl.req.rid
            dec["nout"][s] = len(sl.req.out_tokens)

        table = self.table.copy()
        if pf_work is not None:
            pf_slot, pf, bucket, nvalid = pf_work
            step_fn = self._get_step(bucket)
            self.caches, toks, pf_token = step_fn(
                self.params, self.caches, table, np.int32(self.seed),
                dec, pf)
        else:
            step_fn = self._get_step(None)
            self.caches, toks, pf_token = step_fn(
                self.params, self.caches, table, np.int32(self.seed), dec)
        self.dispatch_count += 1
        toks = np.asarray(toks)

        if pf_work is not None:
            sl = self.slots[pf_slot]
            sl.pos += nvalid
            if sl.pos >= len(sl.req.prompt):
                sl.prefilled = True
                sl.req.out_tokens.append(int(pf_token))
                if len(sl.req.out_tokens) >= sl.req.max_new_tokens:
                    self._retire(pf_slot)

        for s in dec_slots:
            sl = self.slots[s]
            sl.req.out_tokens.append(int(toks[s]))
            sl.pos += 1
            if len(sl.req.out_tokens) >= sl.req.max_new_tokens:
                self._retire(s)
        return True

    def run(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return self.finished

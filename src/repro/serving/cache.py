"""Paged (blocked) decode-cache backend for the serving engine.

Dense serving caches reserve `batch_size x max_len` KV worst-case per
full-attention sublayer. The paged backend replaces each of those caches
with a shared BLOCK POOL plus per-slot block tables (vLLM-style):

    pool  {"k"/"v": (G, n_blocks, block_size, kv_heads, head_dim),
           "pos"/"valid": (G, n_blocks, block_size)}
    table (batch, max_blocks) int32 rows of pool block ids

so persistent memory scales with LIVE TOKENS (allocated blocks), not the
worst case. Block 0 is reserved as the never-allocated null block —
padding table entries point at it, it is never written, and its `valid`
bits stay False, so gathered views through it mask cleanly.

Only full-attention sublayers page: a sliding-window cache is already a
bounded per-slot ring, and SSM/conv state is O(1) per slot. Cross-attn
caches are filled once at admission and stay dense.

Allocation is host-side (`BlockAllocator` free list); the jitted step
only ever sees the pool + tables, so admission/retirement never
recompiles anything.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.backbone import init_decode_caches

_ATTN_KINDS = ("attn", "attn_local", "attn_global", "shared_attn")


def paged_sub_names(cfg: ArchConfig) -> tuple:
    """The 'subI' pattern entries that page: full-attention sublayers."""
    return tuple(
        f"sub{i}" for i, kind in enumerate(cfg.group_pattern)
        if kind in _ATTN_KINDS and cfg.sublayer_window(kind) is None)


def slot_max_blocks(max_len: int, block_size: int) -> int:
    return -(-max_len // block_size)


def _block_pool(cfg: ArchConfig, n_blocks: int, block_size: int, dtype):
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    return {
        "k": jnp.zeros((n_blocks, block_size, kv, hd), dtype=dtype),
        "v": jnp.zeros((n_blocks, block_size, kv, hd), dtype=dtype),
        "pos": jnp.zeros((n_blocks, block_size), dtype=jnp.int32),
        "valid": jnp.zeros((n_blocks, block_size), dtype=bool),
    }


def init_paged_caches(cfg: ArchConfig, batch: int, max_len: int, *,
                      block_size: int, n_blocks: Optional[int] = None,
                      dtype=jnp.float32):
    """Serving caches with full-attention sublayers replaced by block
    pools (leading group axis kept for the backbone scan).

    n_blocks defaults to the dense worst case (batch x max_blocks + the
    null block); pass less to cap pool memory — admission then queues
    when the pool is exhausted. Returns (caches, meta).
    """
    mb = slot_max_blocks(max_len, block_size)
    if n_blocks is None:
        n_blocks = batch * mb + 1
    caches = init_decode_caches(cfg, batch, max_len, dtype=dtype)
    g = cfg.n_groups_stack
    paged = paged_sub_names(cfg)
    for name in paged:
        pool = _block_pool(cfg, n_blocks, block_size, dtype)
        caches[name] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (g,) + x.shape).copy(), pool)
    meta = {"block_size": block_size, "n_blocks": n_blocks,
            "max_blocks": mb, "paged_subs": paged}
    return caches, meta


def invalidate_blocks(caches, paged_subs, block_ids):
    """Mark pool blocks `block_ids` (padded with 0 — the null block is
    idempotently already-invalid) as invalid in every paged sublayer.
    Called on request retirement so reused blocks never leak stale
    valid entries into a later owner's gathered view."""
    out = dict(caches)
    for name in paged_subs:
        sub = caches[name]
        out[name] = {**sub,
                     "valid": sub["valid"].at[:, block_ids].set(False)}
    return out


def cache_bytes(caches) -> int:
    """Persistent cache footprint in bytes (pools + dense leaves)."""
    return int(sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(caches)))


class BlockAllocator:
    """Host-side free list over pool blocks 1..n_blocks-1 (0 is null)."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free = list(range(n_blocks - 1, 0, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int):
        """Pop n block ids, or None when the pool can't satisfy it."""
        if n == 0:
            return []
        if n > len(self._free):
            return None
        got = self._free[-n:][::-1]
        del self._free[-n:]
        return got

    def free(self, block_ids):
        for b in block_ids:
            assert 0 < b < self.n_blocks
            self._free.append(b)

from repro.sharding.rules import (
    ParallelismPlan,
    plan_for,
    param_specs,
    cache_specs,
    state_specs,
    data_spec,
    enc_feats_spec,
)

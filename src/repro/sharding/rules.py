"""Parameter / activation / cache sharding rules.

Megatron-style tensor parallelism on the `model` axis plus optional
FSDP over the device axes for the largest generators:

  * "in" projections  (wq wk wv w_in w_gate in_proj z_proj router):
        tensor-parallel on the OUTPUT dim, FSDP on the input dim
  * "out" projections (wo w_out out_proj lm_head score):
        tensor-parallel on the INPUT dim, FSDP on the output dim
  * embedding tables (vocab, d): d over `model` (vocab sizes are not
        uniformly divisible — e.g. granite's 49155 is odd)
  * vectors / norms / gates: replicated
  * expert tensors (G, E, a, b): same in/out rules on (a, b); the expert
        axis stays unsharded when E doesn't divide the mesh (8, 40 vs 16)
        — expert-parallel rebalancing is a §Perf hillclimb lever.

Decode caches: batch over device axes when divisible, otherwise the
sequence/length dim (long_500k's b=1), which makes GSPMD lower a
distributed flash-decode (sharded softmax reductions + partial-sum
all-reduce).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MeshConfig, ShapeConfig

_IN_PROJ = {"wq", "wk", "wv", "w_in", "w_gate", "in_proj", "z_proj",
            "router", "conv_w", "wqkv", "w_inga"}
_OUT_PROJ = {"wo", "w_out", "out_proj", "lm_head", "score"}
_EMBED = {"table"}

# generators at/above this parameter count get FSDP over the device axes
FSDP_THRESHOLD = 5_000_000_000


@dataclasses.dataclass(frozen=True)
class ParallelismPlan:
    tp_axis: str = "model"
    fsdp_axes: Optional[Tuple[str, ...]] = None    # e.g. ("data",) or ("pod","data")
    dev_axes: Tuple[str, ...] = ("data",)          # the paper's device axes

    def axis_size(self, mesh, name) -> int:
        return mesh.shape[name]


def plan_for(cfg: ArchConfig, mesh_cfg: MeshConfig, *,
             n_params: Optional[int] = None) -> ParallelismPlan:
    dev_axes = ("pod", "data") if mesh_cfg.multi_pod else ("data",)
    fsdp = None
    if mesh_cfg.fsdp or (n_params or _rough_params(cfg)) >= FSDP_THRESHOLD:
        fsdp = dev_axes
    return ParallelismPlan(fsdp_axes=fsdp, dev_axes=dev_axes)


def _rough_params(cfg: ArchConfig) -> int:
    d, L = cfg.d_model, cfg.n_layers
    per_layer = 4 * d * d * (1 if cfg.family in ("ssm",) else 1)
    if cfg.moe:
        per_layer += 3 * d * cfg.moe.d_ff_expert * cfg.moe.n_experts
    else:
        per_layer += 3 * d * cfg.d_ff
    return L * per_layer + 2 * cfg.vocab * d


def _norm(axes):
    """PartitionSpec entry: unwrap 1-tuples. Newer jax normalizes these
    at construction; older (0.4.x) keeps the tuple, which breaks spec
    equality even though GSPMD treats them identically."""
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def _divisible(dim: int, mesh, axes) -> bool:
    if axes is None:
        return False
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return dim > 0 and dim % size == 0


def _leaf_spec(path_names, leaf, mesh, plan: ParallelismPlan,
               fsdp: bool) -> P:
    name = path_names[-1]
    shape = leaf.shape
    ndim = len(shape)
    tp = plan.tp_axis
    fsdp_axes = plan.fsdp_axes if fsdp else None

    if ndim <= 1:
        return P()
    if name in _EMBED:
        spec = [None] * ndim
        if _divisible(shape[-1], mesh, tp):
            spec[-1] = tp
        return P(*spec)
    if name in _IN_PROJ:
        spec = [None] * ndim
        if _divisible(shape[-1], mesh, tp):
            spec[-1] = tp
        if ndim >= 2 and fsdp_axes and _divisible(shape[-2], mesh, fsdp_axes):
            spec[-2] = _norm(fsdp_axes)
        return P(*spec)
    if name in _OUT_PROJ:
        spec = [None] * ndim
        if ndim >= 2 and _divisible(shape[-2], mesh, tp):
            spec[-2] = tp
        if fsdp_axes and _divisible(shape[-1], mesh, fsdp_axes):
            spec[-1] = _norm(fsdp_axes)
        return P(*spec)
    return P()


def param_specs(params, mesh, plan: ParallelismPlan, *, fsdp: bool = False):
    """Pytree of PartitionSpecs matching `params`."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        specs.append(_leaf_spec(names, leaf, mesh, plan, fsdp))
    return jax.tree_util.tree_unflatten(treedef, specs)


def stacked_specs(tree, mesh, plan: ParallelismPlan):
    """Specs for per-device stacked trees (leading K axis over dev_axes)."""
    inner = param_specs(jax.tree.map(lambda x: x[0], tree), mesh, plan)
    return jax.tree.map(
        lambda s: P(_norm(plan.dev_axes), *s), inner,
        is_leaf=lambda s: isinstance(s, P))


def state_specs(state, mesh, plan: ParallelismPlan, *, gen_fsdp: bool):
    """Shardings for the protocol TrainState
    {"gen","disc","gen_opt","disc_opt"(stacked)}."""
    return {
        "gen": param_specs(state["gen"], mesh, plan, fsdp=gen_fsdp),
        "disc": param_specs(state["disc"], mesh, plan, fsdp=False),
        "gen_opt": param_specs_opt(state["gen_opt"], state["gen"], mesh, plan,
                                   fsdp=gen_fsdp),
        "disc_opt": stacked_opt_specs(state["disc_opt"], state["disc"], mesh,
                                      plan),
    }


def param_specs_opt(opt_state, params, mesh, plan, *, fsdp: bool):
    """Optimizer moments share their parameter's sharding; scalars replicate."""
    pspecs = param_specs(params, mesh, plan, fsdp=fsdp)

    def match(node):
        if isinstance(node, dict) and set(node) == set(("m", "v", "t")):
            return {"m": pspecs, "v": pspecs, "t": P()}
        if isinstance(node, dict) and set(node) == set(("mu",)):
            return {"mu": pspecs}
        return jax.tree.map(lambda _: P(), node)

    return match(opt_state)


def stacked_opt_specs(opt_state, params, mesh, plan):
    inner = param_specs(params, mesh, plan, fsdp=False)
    stacked = jax.tree.map(lambda s: P(_norm(plan.dev_axes), *s), inner,
                           is_leaf=lambda s: isinstance(s, P))

    def match(node):
        if isinstance(node, dict) and set(node) == set(("m", "v", "t")):
            return {"m": stacked, "v": stacked, "t": P(_norm(plan.dev_axes))}
        if isinstance(node, dict) and set(node) == set(("mu",)):
            return {"mu": stacked}
        return jax.tree.map(lambda _: P(_norm(plan.dev_axes)), node)

    return match(opt_state)


def data_spec(plan: ParallelismPlan):
    """Token shards (K, n_k, seq): device axis over the paper's devices."""
    return P(_norm(plan.dev_axes))


def enc_feats_spec(cfg: ArchConfig, mesh, plan: ParallelismPlan):
    """(n, t, d_model) stub frontend features."""
    spec = [None, None, None]
    if _divisible(cfg.d_model, mesh, plan.tp_axis):
        spec[-1] = plan.tp_axis
    return P(*spec)


# ---------------------------------------------------------------------------
# shard_map (mesh-layout) specs — explicit-collective protocol rounds
# ---------------------------------------------------------------------------

# In-slice tensor parallelism (the mesh layout's `model` axis): which
# leaf NAMES carry a Megatron shard, and on which dim. Column-parallel
# weights (and their biases) shard the output dim; row-parallel weights
# shard the input dim. Negative dims make the same rule cover plain
# params, optimizer moments (same leaf names under m/v/mu), and
# device-stacked trees (the leading K axis shifts positive indices but
# not negative ones). Leaves with other names (attention, norms, convs,
# embeds, ssm) replicate over the model axis — and so does EVERYTHING
# under an "experts" subtree: MoE experts reuse the mlp leaf names but
# `moe_apply` has no in-slice collectives, so sharding them would
# silently drop the cross-rank reduction (expert parallelism is an
# open ROADMAP item; `make_backbone_spec` rejects moe + tp_axis).
# TP-named leaves whose dim tp doesn't divide are an ERROR, not a
# replication fallback — see tp_leaf_dim.
_TP_COL = {"w_in", "w_gate", "b_in"}      # output-dim shard
_TP_ROW = {"w_out"}                       # input-dim shard
_TP_REPLICATED_SUBTREES = {"experts"}


def tp_leaf_dim(name: str, shape, tp: int):
    """The model-axis shard dim of one leaf (negative), or None when the
    leaf replicates by name.

    A TP-NAMED leaf whose shard dim `tp` doesn't divide RAISES instead
    of silently replicating: unlike the GSPMD rules above (where the
    compiler inserts the collectives, so replication is a safe
    fallback), the manual Megatron apply path psums unconditionally —
    a replicated leaf would have its outputs inflated by exactly tp.
    """
    if tp <= 1:
        return None
    if name in _TP_COL and len(shape) >= 1:
        dim = -1
    elif name in _TP_ROW and len(shape) >= 2:
        dim = -2
    else:
        return None
    if shape[dim] % tp != 0:
        raise ValueError(
            f"tensor-parallel leaf {name!r} {tuple(shape)}: shard dim "
            f"{shape[dim]} is not divisible by tp={tp} — the Megatron "
            f"apply path would psum un-sharded products (outputs x{tp}); "
            f"pick a divisible width or a different tp")
    return dim


def _tp_path_dim(path_names, shape, tp: int):
    """`tp_leaf_dim` with the leaf's PATH context: any leaf under a
    replicated subtree (MoE experts) stays replicated regardless of
    its name."""
    if any(n in _TP_REPLICATED_SUBTREES for n in path_names):
        return None
    name = path_names[-1] if path_names else ""
    return tp_leaf_dim(name, shape, tp)


def tp_tree_dims(tree, tp: int):
    """Shard dims for every leaf of `tree`, as a tuple aligned with
    `jax.tree_util.tree_flatten(tree)` order (None entries don't
    survive a pytree, so the aligned-tuple form is the contract —
    `quantize.roundtrip_tp` consumes it the same way).

    IMPORTANT: call this on GLOBAL-shaped trees. Divisibility is
    decided on the global dim; deciding it again on local shards could
    disagree (e.g. global 6 % 2 == 0 but local 3 % 2 != 0).
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    dims = []
    for path, leaf in flat:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        dims.append(_tp_path_dim(names, leaf.shape, tp))
    return tuple(dims)


def tp_local_size(tree, tp: int) -> int:
    """Per-TP-rank element count of `tree` (global): sharded leaves
    contribute size/tp — the Algorithm-2 all-gather payload per slice."""
    flat = jax.tree_util.tree_leaves(tree)
    dims = tp_tree_dims(tree, tp)
    return sum(int(x.size) // (tp if d is not None else 1)
               for x, d in zip(flat, dims))


def tree_specs(tree, spec_leaf: P):
    """Broadcast one PartitionSpec over every leaf of `tree` (None leaves
    included, as optimizer states may carry them)."""
    return jax.tree.map(lambda _: spec_leaf, tree,
                        is_leaf=lambda x: x is None)


def _tp_entry_specs(tree, device_axes, stacked: bool, tp_axis: str,
                    tp: int):
    """Per-leaf specs for ONE TrainState entry with in-slice TP: the
    model axis lands on the leaf's shard dim, the device axes on dim 0
    of stacked entries."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    specs = []
    for path, leaf in flat:
        if leaf is None:
            specs.append(P())
            continue
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        ndim = len(leaf.shape)
        dim = _tp_path_dim(names, leaf.shape, tp)
        entries = [None] * ndim
        if stacked and ndim >= 1:
            entries[0] = _norm(device_axes)
        if dim is not None:
            entries[ndim + dim] = tp_axis
        while entries and entries[-1] is None:   # P(None) != P() on 0.4.x
            entries.pop()
        specs.append(P(*entries))
    return jax.tree_util.tree_unflatten(treedef, specs)


def tp_param_specs(tree, tp_axis: str, tp: int):
    """Public per-leaf shard_map specs for a bare parameter tree with
    in-slice Megatron TP: each TP-named leaf (`tp_leaf_dim` name rules)
    carries `tp_axis` on its shard dim, everything else replicates.

    This is the train-to-serve contract: a serving engine wraps its
    decode step in shard_map with these in_specs and an unmodified
    GLOBAL-shaped training checkpoint shards on entry, exactly as
    `shard_round_state_specs` shards it for training. Call with the
    GLOBAL tree (divisibility is decided on global dims)."""
    return _tp_entry_specs(tree, (), False, tp_axis, tp)


def shard_round_state_specs(state, device_axes,
                            stacked_keys=("disc_opt",),
                            tp_axis=None, tp: int = 1) -> dict:
    """shard_map in/out specs for a TrainState under the mesh layout.

    Entries in `stacked_keys` carry a leading K axis stacked over the
    device axes (each slice IS one of the paper's K devices); the rest
    replicate over the device axes (the server is shared-seed replicated
    computation). Proposed protocol: only `disc_opt` is per-device.
    FedGAN: both optimizer states are per-device (`gen_opt` AND
    `disc_opt`), since every device trains a local generator too.

    With `tp_axis`/`tp` set (the 2-D device x model mesh), TP-shardable
    leaves additionally carry the model axis on their Megatron shard dim
    (`tp_leaf_dim` name rules) in EVERY entry — params, opt moments, and
    stacked trees alike — so shard_map splits/reassembles the global
    state and each slice sees only its parameter shard. Call with the
    GLOBAL state (divisibility is decided on global dims).
    """
    if tp_axis is not None and tp > 1:
        return {k: _tp_entry_specs(v, device_axes, k in stacked_keys,
                                   tp_axis, tp)
                for k, v in state.items()}
    stacked, rep = P(device_axes), P()
    return {k: tree_specs(v, stacked if k in stacked_keys else rep)
            for k, v in state.items()}


# ---------------------------------------------------------------------------
# Serving (cache) shardings
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, caches, batch: int, mesh,
                plan: ParallelismPlan):
    """Specs for decode caches (leading group axis G on every leaf).

    Strategy: shard batch over the device axes when divisible; otherwise
    (long_500k, b=1) shard the KV length dim over (dev_axes + model) for
    distributed flash-decode. kv-heads/head_dim stay unsharded unless
    the batch path already consumed the device axes and kv divides model.
    """
    dev = plan.dev_axes
    tp = plan.tp_axis
    batch_shardable = _divisible(batch, mesh, dev)

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        name = names[-1]
        shape = leaf.shape  # (G, b, ...)
        spec = [None] * len(shape)
        if batch_shardable and len(shape) >= 2 and shape[1] == batch:
            spec[1] = _norm(dev)
        if name in ("k", "v", "pos", "valid") and len(shape) >= 3:
            # length dim is index 2 for k/v (G,b,L,kv,hd) and (G,b,L) for pos
            length = shape[2]
            if not batch_shardable:
                axes = dev + (tp,)
                if _divisible(length, mesh, axes):
                    spec[2] = _norm(axes)
                elif _divisible(length, mesh, dev):
                    spec[2] = _norm(dev)
            elif name in ("k", "v") and _divisible(length, mesh, tp):
                spec[2] = tp
        if name == "ssm" and len(shape) == 5:
            # (G, b, h, n, p): shard heads over model when divisible
            if _divisible(shape[2], mesh, tp):
                spec[2] = tp
        if name == "conv" and len(shape) == 4:
            if _divisible(shape[3], mesh, tp):
                spec[3] = tp
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat])

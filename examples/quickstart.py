"""Quickstart: train a DCGAN with the paper's distributed protocol.

10 simulated devices, serial update schedule, synthetic CelebA-like
data, FID evaluation — a miniature of the paper's Section IV setup.

    PYTHONPATH=src python examples/quickstart.py --rounds 20
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.data import make_image_dataset, partition
from repro.metrics import fid_score, make_feature_extractor
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--devices", type=int, default=10)
    ap.add_argument("--schedule", choices=["serial", "parallel"],
                    default="serial")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = DCGANConfig(nz=32, ngf=16, ndf=16, nc=3, image_size=32)
    spec = make_dcgan_spec(cfg, gen_loss_variant="nonsaturating")
    pcfg = ProtocolConfig(n_devices=args.devices, n_d=2, n_g=2,
                          sample_size=16, server_sample_size=16,
                          lr_d=2e-4, lr_g=2e-4, schedule=args.schedule,
                          optimizer="adam")

    imgs, _ = make_image_dataset("celeba32", 640)
    shards = jnp.asarray(partition(imgs, args.devices))
    feat = make_feature_extractor(cfg.nc)
    real_feats = feat(jnp.asarray(imgs[:512]))

    def fid_fn(gen_params, key):
        z = jax.random.normal(key, (256, cfg.nz))
        return fid_score(real_feats,
                         feat(dcgan.generator_apply(gen_params, cfg, z)))

    trainer = Trainer(spec, pcfg, lambda k: dcgan.gan_init(k, cfg),
                      shards, jax.random.PRNGKey(0))
    trainer.run(args.rounds, eval_every=5, fid_fn=fid_fn, verbose=True)

    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, args.rounds, trainer.state,
                        metadata={"schedule": args.schedule})
        print(f"checkpoint saved to {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""Head-to-head: the proposed framework (serial schedule) vs FedGAN [9]
on the same fleet, data, and channel — miniature of the paper's Fig. 5.

Both algorithms run the fused multi-round driver (one XLA dispatch for
the whole run, FID evaluated in-scan) with the paper's 16-bit quantized
uplink; --bits ablates the uplink width, --driver pins a driver, and
--layout selects the execution layout for BOTH algorithms — the full
layout x algorithm matrix runs this comparison (no silent stacked
assumption):

    PYTHONPATH=src python examples/fedgan_compare.py --rounds 12
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    PYTHONPATH=src python examples/fedgan_compare.py --layout mesh \\
        --devices 8
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import Trainer, protocol, quantize
from repro.configs.dcgan import DCGANConfig
from repro.data import make_image_dataset, partition
from repro.metrics import (feature_stats_jnp, frechet_distance_jnp,
                           make_feature_extractor)
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec


def run(algorithm, schedule, rounds, driver, bits, layout="stacked",
        devices=10, data_size=640):
    cfg = DCGANConfig(nz=32, ngf=16, ndf=16, nc=3, image_size=32)
    spec = make_dcgan_spec(cfg, gen_loss_variant="nonsaturating")
    pcfg = ProtocolConfig(n_devices=devices, n_d=2, n_g=2, sample_size=16,
                          server_sample_size=16, lr_d=2e-4, lr_g=2e-4,
                          schedule=schedule, optimizer="adam",
                          quantize_bits=bits)
    imgs, _ = make_image_dataset("celeba32", data_size)
    shards = jnp.asarray(partition(imgs, devices))
    feat = make_feature_extractor(cfg.nc)
    real_mu, real_cov = feature_stats_jnp(feat(jnp.asarray(imgs[:512])))

    def fid_fn(gen_params, key):
        z = jax.random.normal(key, (256, cfg.nz))
        mu, cov = feature_stats_jnp(
            feat(dcgan.generator_apply(gen_params, cfg, z)))
        return frechet_distance_jnp(real_mu, real_cov, mu, cov)

    tr = Trainer(spec, pcfg, lambda k: dcgan.gan_init(k, cfg), shards,
                 jax.random.PRNGKey(0), algorithm=algorithm,
                 disc_step_flops=1e10, gen_step_flops=1e10, driver=driver,
                 layout=layout)
    hist = tr.run(rounds, eval_every=rounds, fid_fn=fid_fn)
    payload_mbit = protocol.uplink_payload_bits(
        tr.state, pcfg, fedgan=algorithm == "fedgan") / 1e6
    return hist[-1], tr.driver, payload_mbit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--driver", choices=["auto", "fused", "host"],
                    default="auto")
    ap.add_argument("--bits", type=int, default=16,
                    help="uplink quantization width (paper: 16; >=32 "
                         "disables quantization)")
    ap.add_argument("--layout", choices=["stacked", "mesh"],
                    default="stacked",
                    help="execution layout for both algorithms (mesh "
                         "needs >= --devices addressable devices)")
    ap.add_argument("--devices", type=int, default=10,
                    help="fleet size K (the paper's 10)")
    ap.add_argument("--data", type=int, default=640,
                    help="dataset size (shrink for smoke runs)")
    args = ap.parse_args()
    if args.layout == "mesh":
        from repro.launch.mesh import devices_error
        err = devices_error(args.devices)
        if err:
            sys.exit(err)

    prop, d1, mb1 = run("proposed", "serial", args.rounds, args.driver,
                        args.bits, args.layout, args.devices, args.data)
    fed, d2, mb2 = run("fedgan", "serial", args.rounds, args.driver,
                       args.bits, args.layout, args.devices, args.data)
    print(f"proposed-serial : FID={prop.fid:8.2f}  "
          f"wallclock={prop.cumulative_s:8.2f}s  "
          f"uplink={mb1:6.2f} Mbit/round/device  [{d1}]")
    print(f"fedgan          : FID={fed.fid:8.2f}  "
          f"wallclock={fed.cumulative_s:8.2f}s  "
          f"uplink={mb2:6.2f} Mbit/round/device  [{d2}]")
    speedup = fed.cumulative_s / prop.cumulative_s
    print(f"-> proposed finishes the same number of rounds "
          f"{speedup:.2f}x faster in simulated wall-clock "
          f"({mb2 / mb1:.1f}x fewer upload bits, half the device compute)")


if __name__ == "__main__":
    main()

"""Head-to-head: the proposed framework (serial schedule) vs FedGAN [9]
on the same fleet, data, and channel — miniature of the paper's Fig. 5.

    PYTHONPATH=src python examples/fedgan_compare.py --rounds 12
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.data import make_image_dataset, partition
from repro.metrics import fid_score, make_feature_extractor
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec


def run(algorithm, schedule, rounds):
    cfg = DCGANConfig(nz=32, ngf=16, ndf=16, nc=3, image_size=32)
    spec = make_dcgan_spec(cfg, gen_loss_variant="nonsaturating")
    pcfg = ProtocolConfig(n_devices=10, n_d=2, n_g=2, sample_size=16,
                          server_sample_size=16, lr_d=2e-4, lr_g=2e-4,
                          schedule=schedule, optimizer="adam")
    imgs, _ = make_image_dataset("celeba32", 640)
    shards = jnp.asarray(partition(imgs, 10))
    feat = make_feature_extractor(cfg.nc)
    real_feats = feat(jnp.asarray(imgs[:512]))

    def fid_fn(gen_params, key):
        z = jax.random.normal(key, (256, cfg.nz))
        return fid_score(real_feats,
                         feat(dcgan.generator_apply(gen_params, cfg, z)))

    tr = Trainer(spec, pcfg, lambda k: dcgan.gan_init(k, cfg), shards,
                 jax.random.PRNGKey(0), algorithm=algorithm,
                 disc_step_flops=1e10, gen_step_flops=1e10)
    hist = tr.run(rounds, eval_every=rounds, fid_fn=fid_fn)
    return hist[-1]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    args = ap.parse_args()

    prop = run("proposed", "serial", args.rounds)
    fed = run("fedgan", "serial", args.rounds)
    print(f"proposed-serial : FID={prop.fid:8.2f}  "
          f"wallclock={prop.cumulative_s:8.2f}s")
    print(f"fedgan          : FID={fed.fid:8.2f}  "
          f"wallclock={fed.cumulative_s:8.2f}s")
    speedup = fed.cumulative_s / prop.cumulative_s
    print(f"-> proposed finishes the same number of rounds "
          f"{speedup:.2f}x faster in simulated wall-clock "
          f"(half the upload bytes, half the device compute)")


if __name__ == "__main__":
    main()

"""End-to-end driver: the distributed GAN protocol on an ASSIGNED
backbone architecture over synthetic token data.

By default this trains the reduced variant of the chosen architecture
(CPU-sized); pass --full-scale to build the full assigned config (only
sensible on a real accelerator cluster — the same code path the
multi-pod dry-run lowers).

    PYTHONPATH=src python examples/train_distgan.py --arch qwen3-1.7b \
        --rounds 30 --devices 4
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch_config, list_archs
from repro.configs.base import ProtocolConfig
from repro.core import Trainer
from repro.data import make_token_dataset, partition
from repro.metrics import fid_score
from repro.metrics.fid import make_token_feature_extractor
from repro.models import gan
from repro.models.specs import make_backbone_spec, make_stub_enc_feats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-1.7b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--schedule", choices=["serial", "parallel"],
                    default="serial")
    ap.add_argument("--driver", choices=["fused", "host"], default="fused",
                    help="fused = R rounds per XLA dispatch (lax.scan); "
                         "host = one dispatch per round (oracle path)")
    ap.add_argument("--full-scale", action="store_true",
                    help="build the full assigned config (cluster only)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_arch_config(args.arch)
    if not args.full_scale:
        cfg = cfg.reduced()
    print(f"[train_distgan] {cfg.name} ({cfg.family}), "
          f"{args.devices} devices, schedule={args.schedule}, "
          f"driver={args.driver}")

    pcfg = ProtocolConfig(n_devices=args.devices, n_d=2, n_g=2,
                          sample_size=4, server_sample_size=4,
                          lr_d=1e-3, lr_g=1e-3, schedule=args.schedule,
                          optimizer="adam")
    enc_fn = make_stub_enc_feats(cfg)
    spec = make_backbone_spec(cfg, args.seq_len, enc_feats_fn=enc_fn,
                              remat=False,
                              gen_loss_variant="nonsaturating")

    toks, _ = make_token_dataset(args.devices * 32, args.seq_len,
                                 cfg.vocab)
    shards = jnp.asarray(partition(toks, args.devices))

    feat = make_token_feature_extractor(cfg.vocab)
    real_feats = feat(jnp.asarray(toks[: 128]))

    def fid_fn(gen_params, key):
        z = spec.sample_z(key, 64)
        fake = spec.gen_apply(gen_params, z)   # embedding sequences
        return fid_score(real_feats, feat(fake))

    trainer = Trainer(spec, pcfg,
                      lambda k: gan.gan_init(k, cfg), shards,
                      jax.random.PRNGKey(0), driver=args.driver)
    t0 = time.time()
    trainer.run(args.rounds, eval_every=max(args.rounds // 4, 1),
                fid_fn=fid_fn, verbose=True)
    print(f"[train_distgan] {args.rounds} rounds in {time.time()-t0:.1f}s")

    if args.ckpt_dir:
        from repro.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt_dir, args.rounds, trainer.state,
                        metadata={"arch": cfg.name})
        print(f"checkpoint saved to {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""Serve a generator-as-LM with batched requests through the slot-based
continuous-batching engine — the runnable counterpart of the decode
dry-run shapes.

    PYTHONPATH=src python examples/serve_generator.py --arch mamba2-130m
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_arch_config, list_archs
from repro.models import gan
from repro.serving import ServingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="mamba2-130m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch_config(args.arch).reduced()
    print(f"[serve] {cfg.name} reduced variant, "
          f"batch={args.batch_size}, requests={args.requests}")
    params = gan.generator_init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, batch_size=args.batch_size,
                           max_len=64)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab,
                              rng.integers(3, 10)).astype(np.int32)
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature))
    finished = engine.run()
    dt = time.time() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished)
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> "
              f"{r.out_tokens}")
    print(f"[serve] {len(finished)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()

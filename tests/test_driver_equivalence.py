"""Fused-driver equivalence: the compiled multi-round scan must
reproduce the per-round host loop, and the pure-JAX scheduler/channel
twins must agree with their numpy oracles.

Contract (see core/engine.py, core/protocol.py docstrings), for BOTH
the proposed protocol and FedGAN (the unified engine):
  * params/metrics: float32 round-off agreement, any scheduler
  * scheduler masks: BITWISE agreement for deterministic policies
  * wallclock: float32 round-off agreement when fading=False (with
    fading the streams differ, distribution-level only)
  * the quantized uplink (bits < 32) draws per-device streams from the
    round key alone, so both drivers quantize bitwise-identically

The full FedGAN matrix (schedules x fading x bits) is `slow`-marked and
runs in CI's slow lane; one representative combo stays in the fast lane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer, protocol
from repro.core.channel import ChannelConfig, ChannelSimulator, round_wallclock
from repro.core.jax_channel import JaxChannel
from repro.core.jax_channel import round_wallclock as jax_round_wallclock
from repro.core.jax_scheduling import JaxScheduler, schedule_step
from repro.core.scheduling import SchedulerState, schedule_round
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)
# 8x8 two-stage DCGAN: small enough that many-round runs stay cheap
CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
SPEC = make_dcgan_spec(CFG)
K = 4
DATA = jax.random.normal(jax.random.PRNGKey(9), (K, 8, 8, 8, 1))


def make_trainer(driver, *, algorithm="proposed", schedule="serial",
                 scheduler="all", ratio=1.0, bits=16, channel_kw=None):
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                          schedule=schedule, scheduler=scheduler,
                          scheduling_ratio=ratio, quantize_bits=bits)
    chan = ChannelConfig(n_devices=K, seed=3, **(channel_kw or {}))
    return Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), DATA, KEY,
                   channel_cfg=chan, driver=driver, algorithm=algorithm)


def assert_trees_close(a, b, atol=2e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


def assert_histories_match(host_hist, fused_hist, *, wallclock=False):
    assert len(host_hist) == len(fused_hist)
    for rh, rf in zip(host_hist, fused_hist):
        assert rh.round == rf.round
        np.testing.assert_array_equal(rh.mask, rf.mask)   # bitwise
        for k in rh.metrics:
            assert abs(rh.metrics[k] - rf.metrics[k]) < 1e-4, \
                (rh.round, k, rh.metrics[k], rf.metrics[k])
        if wallclock:
            np.testing.assert_allclose(rh.wallclock_s, rf.wallclock_s,
                                       rtol=1e-5)


class TestFusedVsHostLoop:
    @pytest.mark.parametrize("schedule", ["serial", "parallel"])
    def test_fused_matches_host_over_rounds(self, schedule):
        """Satellite (a): >=5 rounds, params + per-round metrics + masks."""
        th = make_trainer("host", schedule=schedule)
        tf = make_trainer("fused", schedule=schedule)
        h, f = th.run(6), tf.run(6)
        assert_trees_close(th.state, tf.state)
        assert_histories_match(h, f)

    def test_round_robin_masks_and_wallclock_fading_off(self):
        """Deterministic channel: masks bitwise AND wallclock to f32
        round-off, while the cursor wraps (K=4, n=2 -> period 2)."""
        kw = dict(scheduler="round_robin", ratio=0.5,
                  channel_kw={"fading": False})
        th = make_trainer("host", **kw)
        tf = make_trainer("fused", **kw)
        h, f = th.run(5), tf.run(5)
        assert_trees_close(th.state, tf.state)
        assert_histories_match(h, f, wallclock=True)
        # the rotating window actually rotated
        assert (h[0].mask != h[1].mask).any()
        np.testing.assert_array_equal(h[0].mask, h[2].mask)

    def test_chunked_fused_run_matches_one_shot(self):
        """run(2) + run(4) must equal run(6): the scheduler carry and the
        absolute round index survive chunk boundaries."""
        ta = make_trainer("fused", scheduler="round_robin", ratio=0.5)
        tb = make_trainer("fused", scheduler="round_robin", ratio=0.5)
        ta.run(2)
        ta.run(4)
        tb.run(6)
        assert_trees_close(ta.state, tb.state)
        assert_histories_match(ta.history, tb.history)

    def test_fused_straggler_exclusion_matches_weights(self):
        """A sub-round deadline makes every scheduled device a straggler:
        weights go to zero and wallclock is the broadcast-only path —
        identically in both drivers."""
        kw = dict(channel_kw={"fading": False,
                              "straggler_deadline_s": 1e-9})
        th = make_trainer("host", **kw)
        tf = make_trainer("fused", **kw)
        h, f = th.run(3), tf.run(3)
        assert_histories_match(h, f, wallclock=True)
        assert all(r.metrics["participation"] == 0.0 for r in f)
        assert_trees_close(th.state, tf.state)


class TestFedganFusedVsHost:
    """The FedGAN baseline gets the SAME pinning the proposed protocol
    has: bitwise masks, float32-tolerance params/metrics, wallclock
    parity with fading off — across schedules, fading, and uplink
    quantization widths."""

    def _run_pair(self, *, schedule, fading, bits, rounds=4):
        kw = dict(algorithm="fedgan", schedule=schedule, bits=bits,
                  scheduler="round_robin", ratio=0.5,
                  channel_kw={"fading": fading})
        th = make_trainer("host", **kw)
        tf = make_trainer("fused", **kw)
        h, f = th.run(rounds), tf.run(rounds)
        assert_trees_close(th.state, tf.state)
        assert_histories_match(h, f, wallclock=not fading)
        return th, tf

    def test_fedgan_fused_matches_host_fast_lane(self):
        """Fast-lane representative of the matrix below."""
        self._run_pair(schedule="serial", fading=False, bits=16)

    @pytest.mark.slow
    @pytest.mark.parametrize("schedule", ["serial", "parallel"])
    @pytest.mark.parametrize("fading", [False, True])
    @pytest.mark.parametrize("bits", [16, 32])
    def test_fedgan_fused_matches_host_matrix(self, schedule, fading,
                                              bits):
        self._run_pair(schedule=schedule, fading=fading, bits=bits)

    def test_fedgan_quantized_uplink_actually_quantizes(self):
        """bits=8 must change the trajectory vs bits=32 (the uplink is
        exercised, not a no-op) while both drivers still agree."""
        t8 = make_trainer("fused", algorithm="fedgan", bits=8,
                          channel_kw={"fading": False})
        t32 = make_trainer("fused", algorithm="fedgan", bits=32,
                           channel_kw={"fading": False})
        t8.run(2), t32.run(2)
        l8 = jax.tree_util.tree_leaves(t8.state["disc"])
        l32 = jax.tree_util.tree_leaves(t32.state["disc"])
        assert any(float(jnp.abs(a - b).max()) > 1e-7
                   for a, b in zip(l8, l32))

    def test_fedgan_uplink_payload_drives_timing(self):
        """FedGAN's two-net upload must cost more upload time than the
        proposed one-net upload on the same channel, and lower bit
        widths must shrink it."""
        wall = {}
        for alg, bits in (("fedgan", 16), ("fedgan", 8), ("proposed", 16)):
            tr = make_trainer("fused", algorithm=alg, bits=bits,
                              channel_kw={"fading": False})
            wall[alg, bits] = tr.run(1)[0].wallclock_s
        assert wall["fedgan", 16] > wall["proposed", 16]
        assert wall["fedgan", 8] < wall["fedgan", 16]


class TestTrainerCheckpointResume:
    """Satellite: `Trainer.save_checkpoint`/`restore` serialize
    `_round_index`, `_clock`, and the scheduler carry alongside params,
    so a resumed fused run continues masks, params, AND the wallclock
    curve exactly."""

    @pytest.mark.parametrize("algorithm", ["proposed", "fedgan"])
    def test_fused_save_restore_continues_exactly(self, tmp_path,
                                                  algorithm):
        """Kill mid-run, restore, and the wallclock curve and mask
        sequence continue exactly — for BOTH fused algorithms (the
        FedGAN case additionally round-trips the per-device gen_opt
        stack its state carries)."""
        kw = dict(scheduler="round_robin", ratio=0.5, algorithm=algorithm)
        ta = make_trainer("fused", **kw)
        ta.run(3)
        ta.save_checkpoint(str(tmp_path))
        tb = make_trainer("fused", **kw)
        assert tb.restore(str(tmp_path)) == 3
        tb.run(3)
        tc = make_trainer("fused", **kw)
        tc.run(6)
        for a, b in zip(jax.tree_util.tree_leaves(tb.state),
                        jax.tree_util.tree_leaves(tc.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert tb._clock == tc._clock
        assert_histories_match(tc.history[3:], tb.history, wallclock=True)
        # resumed records continue the cumulative wallclock curve exactly
        for rb, rc in zip(tb.history, tc.history[3:]):
            assert rb.cumulative_s == rc.cumulative_s

    def test_fused_resume_carries_fault_state(self, tmp_path):
        """Checkpoint round-trip under a fault program: the stale-upload
        cache (`state["fault"]`) must survive the trip so a resumed run
        reproduces the free-rider replays, masks, and wallclock exactly
        (the fault matrix itself lives in test_faults_equivalence.py)."""
        from repro.core.faults import FaultConfig
        faults = FaultConfig(n_devices=K, dropout_prob=0.3,
                             n_free_riders=1, straggler_factor=2.0)
        pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                              scheduler="round_robin",
                              scheduling_ratio=0.5)
        chan = ChannelConfig(n_devices=K, seed=3, fading=False)

        def make():
            return Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG),
                           DATA, KEY, channel_cfg=chan, driver="fused",
                           faults=faults)

        ta = make()
        ta.run(3)
        ta.save_checkpoint(str(tmp_path))
        tb = make()
        assert tb.restore(str(tmp_path)) == 3
        assert "fault" in tb.state
        tb.run(3)
        tc = make()
        tc.run(6)
        for a, b in zip(jax.tree_util.tree_leaves(tb.state),
                        jax.tree_util.tree_leaves(tc.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert tb._clock == tc._clock
        assert_histories_match(tc.history[3:], tb.history, wallclock=True)

    def test_restore_resumes_scheduler_carry(self, tmp_path):
        """round_robin cursor must survive the round-trip (a fresh carry
        would restart the rotation and change the masks)."""
        kw = dict(scheduler="round_robin", ratio=0.5)
        ta = make_trainer("fused", **kw)
        ta.run(1)                      # cursor now mid-rotation
        ta.save_checkpoint(str(tmp_path))
        tb = make_trainer("fused", **kw)
        tb.restore(str(tmp_path))
        assert int(tb._sched_carry["rr_cursor"]) == \
            int(ta._sched_carry["rr_cursor"]) != 0


class TestMeshLayoutSelection:
    """Fast-lane validation of the layout axis (construction only — the
    8-device execution matrix runs in the mesh lane below)."""

    def test_unknown_layout_raises(self):
        with pytest.raises(ValueError, match="layout"):
            Trainer(SPEC, ProtocolConfig(n_devices=K),
                    lambda k: dcgan.gan_init(k, CFG), DATA, KEY,
                    layout="warp")

    def test_mesh_layout_rejects_centralized(self):
        """centralized has no device structure, so mesh raises — but
        BOTH protocol algorithms are mesh-capable now (the layout x
        algorithm matrix is complete)."""
        from repro.core.engine import MESH_ALGORITHMS
        assert set(MESH_ALGORITHMS) == {"proposed", "fedgan"}
        with pytest.raises(ValueError, match="mesh"):
            Trainer(SPEC, ProtocolConfig(n_devices=K),
                    lambda k: dcgan.gan_init(k, CFG), DATA, KEY,
                    algorithm="centralized", layout="mesh")

    def test_mesh_algorithms_have_fused_entries(self):
        from repro.core.engine import _ALGORITHMS
        for name in ("proposed", "fedgan"):
            algo = _ALGORITHMS[name]
            assert algo.mesh_round is not None
            assert algo.mesh_rounds_scan is not None


class TestRingAvgImplSelection:
    """Fast-lane validation of the `avg_impl` axis (construction only —
    the 8-device ring execution matrix runs in the mesh lane below)."""

    def _trainer(self, **kw):
        return Trainer(SPEC, ProtocolConfig(n_devices=K),
                       lambda k: dcgan.gan_init(k, CFG), DATA, KEY, **kw)

    def test_unknown_avg_impl_raises(self):
        with pytest.raises(ValueError, match="avg_impl"):
            self._trainer(avg_impl="warp")

    def test_ring_requires_mesh_layout(self):
        with pytest.raises(ValueError, match="mesh"):
            self._trainer(avg_impl="ring", layout="stacked")

    def test_ring_rejects_robust_reducer(self):
        with pytest.raises(NotImplementedError, match="robust"):
            self._trainer(avg_impl="ring", layout="mesh",
                          reducer="trimmed_mean")

    def test_ring_rejects_corrupting_faults(self):
        from repro.core.faults import FaultConfig
        with pytest.raises(NotImplementedError, match="corrupt"):
            self._trainer(avg_impl="ring", layout="mesh",
                          faults=FaultConfig(n_devices=K, n_byzantine=1))
        # dropout-only fault programs compose: they only zero weights
        from repro.core import shard_round
        shard_round.check_ring_support(
            "ring", ("data",), None, 1,
            FaultConfig(n_devices=K, dropout_prob=0.5), None)

    def test_ring_rejects_tp_and_multi_axis(self):
        from repro.core import shard_round
        with pytest.raises(NotImplementedError, match="tensor parallel"):
            shard_round.check_ring_support("ring", ("data",), "model", 2,
                                           None, None)
        with pytest.raises(NotImplementedError, match="single device"):
            shard_round.check_ring_support("ring", ("rows", "cols"),
                                           None, 1, None, None)


class TestShardRoundBuilderMemo:
    """The shard_map builders memoize on their full (mesh, config)
    signature, so repeated Trainer constructions in one process reuse
    the jitted closures (and their compiles) instead of rebuilding per
    call. A 1x1 host mesh suffices — construction only."""

    def _args(self):
        from repro.launch.mesh import make_host_mesh
        pcfg = ProtocolConfig(n_devices=1, n_d=1, n_g=1, sample_size=2,
                              server_sample_size=2)
        return SPEC, pcfg, make_host_mesh(1, 1)

    def test_single_round_builders_memoize(self):
        from repro.core import shard_round
        spec, pcfg, mesh = self._args()
        a = shard_round.shard_map_round(spec, pcfg, mesh)
        b = shard_round.shard_map_round(spec, pcfg, mesh)
        assert a is b
        c = shard_round.fedgan_shard_map_round(spec, pcfg, mesh)
        assert c is shard_round.fedgan_shard_map_round(spec, pcfg, mesh)
        assert c is not a

    def test_scan_builders_memoize_and_key_on_config(self):
        import dataclasses as dc
        from repro.core import shard_round
        from repro.core.jax_channel import JaxChannel
        from repro.core.jax_scheduling import JaxScheduler
        spec, pcfg, mesh = self._args()
        chan_cfg = ChannelConfig(n_devices=1, seed=3)
        sched = JaxScheduler(policy="all", n_devices=1)
        kw = dict(channel=JaxChannel(chan_cfg), scheduler=sched)
        a = shard_round.shard_rounds_scan(spec, pcfg, mesh, 2, **kw)
        # a DIFFERENT JaxChannel instance with an EQUAL config still hits
        b = shard_round.shard_rounds_scan(spec, pcfg, mesh, 2,
                                          channel=JaxChannel(chan_cfg),
                                          scheduler=sched)
        assert a is b
        # any config change misses: round count, pcfg, channel config
        assert shard_round.shard_rounds_scan(spec, pcfg, mesh, 3,
                                             **kw) is not a
        pcfg2 = dc.replace(pcfg, quantize_bits=8)
        assert shard_round.shard_rounds_scan(spec, pcfg2, mesh, 2,
                                             **kw) is not a
        chan2 = JaxChannel(ChannelConfig(n_devices=1, seed=4))
        assert shard_round.shard_rounds_scan(spec, pcfg, mesh, 2,
                                             channel=chan2,
                                             scheduler=sched) is not a

    def test_eval_fn_closures_never_memoized(self):
        from repro.core import shard_round
        from repro.core.jax_channel import JaxChannel
        from repro.core.jax_scheduling import JaxScheduler
        spec, pcfg, mesh = self._args()
        kw = dict(channel=JaxChannel(ChannelConfig(n_devices=1, seed=3)),
                  scheduler=JaxScheduler(policy="all", n_devices=1),
                  eval_fn=lambda g, t, k: 0.0, eval_every=2)
        a = shard_round.shard_rounds_scan(spec, pcfg, mesh, 2, **kw)
        assert shard_round.shard_rounds_scan(spec, pcfg, mesh, 2,
                                             **kw) is not a

    def test_memoized_trainer_reuses_mesh_round(self):
        """Two Trainers sharing spec/pcfg/mesh config reuse ONE mesh
        round builder — the satellite's actual target."""
        pcfg = ProtocolConfig(n_devices=1, n_d=1, n_g=1, sample_size=2,
                              server_sample_size=2)
        data = DATA[:1]
        chan = ChannelConfig(n_devices=1, seed=3)
        ta = Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), data,
                     KEY, channel_cfg=chan, driver="host", layout="mesh")
        tb = Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), data,
                     KEY, channel_cfg=chan, driver="host", layout="mesh")
        assert ta._round is tb._round


class TestMeshFusedEquivalence:
    """Satellite: the FULL layout x algorithm matrix — mesh-fused vs
    stacked-fused vs host oracle, for BOTH the proposed protocol and
    FedGAN, over schedules x quantize_bits, on a forced 8-device host
    mesh. The whole matrix runs in ONE subprocess (the jax startup
    dominates); masks must agree BITWISE across all three drivers and
    params to float32 tolerance. Resume is checked for both algorithms
    on the mesh layout. Runs in CI's mesh lane."""

    @pytest.mark.slow
    def test_mesh_matrix_and_resume_on_8_device_mesh(self):
        from conftest import run_on_host_mesh
        run_on_host_mesh("""
            import itertools, tempfile
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ProtocolConfig
            from repro.configs.dcgan import DCGANConfig
            from repro.core import Trainer
            from repro.core.channel import ChannelConfig
            from repro.models import dcgan
            from repro.models.specs import make_dcgan_spec

            KEY = jax.random.PRNGKey(0)
            CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
            SPEC = make_dcgan_spec(CFG)
            K = 8
            DATA = jax.random.normal(jax.random.PRNGKey(9),
                                     (K, 8, 8, 8, 1))

            def make(driver, layout, schedule, bits, algorithm):
                pcfg = ProtocolConfig(
                    n_devices=K, n_d=1, n_g=1, sample_size=4,
                    server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                    schedule=schedule, scheduler="round_robin",
                    scheduling_ratio=0.5, quantize_bits=bits)
                chan = ChannelConfig(n_devices=K, seed=3, fading=False)
                return Trainer(SPEC, pcfg,
                               lambda k: dcgan.gan_init(k, CFG), DATA,
                               KEY, channel_cfg=chan, driver=driver,
                               layout=layout, algorithm=algorithm)

            def leaves(t):
                return jax.tree_util.tree_leaves(t.state)

            for algorithm, schedule, bits in itertools.product(
                    ("proposed", "fedgan"), ("serial", "parallel"),
                    (16, 32)):
                th = make("host", "stacked", schedule, bits, algorithm)
                ts = make("fused", "stacked", schedule, bits, algorithm)
                tm = make("fused", "mesh", schedule, bits, algorithm)
                h, s, m = th.run(4), ts.run(4), tm.run(4)
                for rh, rs, rm in zip(h, s, m):
                    np.testing.assert_array_equal(rh.mask, rs.mask)
                    np.testing.assert_array_equal(rh.mask, rm.mask)
                    for k in rh.metrics:
                        assert abs(rh.metrics[k] - rm.metrics[k]) < 1e-4
                    np.testing.assert_allclose(rh.wallclock_s,
                                               rm.wallclock_s, rtol=1e-5)
                for a, b in zip(leaves(th), leaves(tm)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=2e-5)
                for a, b in zip(leaves(ts), leaves(tm)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=2e-5)
                print(f"matrix OK algorithm={algorithm} "
                      f"schedule={schedule} bits={bits}")

            # mesh+host (per-round shard_map dispatch) agrees too —
            # one representative per algorithm
            for algorithm in ("proposed", "fedgan"):
                th = make("host", "stacked", "serial", 16, algorithm)
                tm = make("host", "mesh", "serial", 16, algorithm)
                h, m = th.run(3), tm.run(3)
                for rh, rm in zip(h, m):
                    np.testing.assert_array_equal(rh.mask, rm.mask)
                for a, b in zip(leaves(th), leaves(tm)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=2e-5)
                print(f"mesh host driver OK algorithm={algorithm}")

            # resumed mesh runs continue the wallclock curve and mask
            # sequence exactly — both algorithms
            for algorithm in ("proposed", "fedgan"):
                d = tempfile.mkdtemp()
                ta = make("fused", "mesh", "serial", 16, algorithm)
                ta.run(2)
                ta.save_checkpoint(d)
                tb = make("fused", "mesh", "serial", 16, algorithm)
                tb.restore(d)
                tb.run(2)
                tc = make("fused", "mesh", "serial", 16, algorithm)
                tc.run(4)
                for a, b in zip(leaves(tb), leaves(tc)):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
                assert tb._clock == tc._clock
                for rb, rc in zip(tb.history, tc.history[2:]):
                    assert rb.cumulative_s == rc.cumulative_s
                    np.testing.assert_array_equal(rb.mask, rc.mask)
                print(f"mesh resume OK algorithm={algorithm}")
        """)

    @pytest.mark.slow
    def test_mesh_ring_avg_impl_matches_host_and_flat(self):
        """PR 9 tentpole acceptance: `avg_impl="ring"` on the fused mesh
        engine reproduces the host oracle and the flat pallas mesh path
        for BOTH algorithms x bits in {16, 32} — masks BITWISE, params
        to float32 round-off (the ring changes reduction ORDER, so the
        tolerance covers cross-rank accumulation rotation, not values:
        the quantized wire realizes the same `quantize_tree` streams).
        Also pins the mesh twin of tests/test_no_survivor.py: ring +
        FaultConfig(dropout_prob=1.0) freezes the disc exactly."""
        from conftest import run_on_host_mesh
        run_on_host_mesh("""
            import itertools
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ProtocolConfig
            from repro.configs.dcgan import DCGANConfig
            from repro.core import Trainer
            from repro.core.channel import ChannelConfig
            from repro.core.faults import FaultConfig
            from repro.models import dcgan
            from repro.models.specs import make_dcgan_spec

            KEY = jax.random.PRNGKey(0)
            CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
            SPEC = make_dcgan_spec(CFG)
            K = 8
            DATA = jax.random.normal(jax.random.PRNGKey(9),
                                     (K, 8, 8, 8, 1))

            def make(driver, layout, bits, algorithm, avg_impl="pallas",
                     faults=None):
                pcfg = ProtocolConfig(
                    n_devices=K, n_d=1, n_g=1, sample_size=4,
                    server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                    scheduler="round_robin", scheduling_ratio=0.5,
                    quantize_bits=bits)
                chan = ChannelConfig(n_devices=K, seed=3, fading=False)
                return Trainer(SPEC, pcfg,
                               lambda k: dcgan.gan_init(k, CFG), DATA,
                               KEY, channel_cfg=chan, driver=driver,
                               layout=layout, algorithm=algorithm,
                               avg_impl=avg_impl, faults=faults)

            def leaves(t):
                return jax.tree_util.tree_leaves(t.state)

            for algorithm, bits in itertools.product(
                    ("proposed", "fedgan"), (16, 32)):
                th = make("host", "stacked", bits, algorithm)
                tp = make("fused", "mesh", bits, algorithm)
                tr = make("fused", "mesh", bits, algorithm,
                          avg_impl="ring")
                h, p, r = th.run(4), tp.run(4), tr.run(4)
                for rh, rp, rr in zip(h, p, r):
                    np.testing.assert_array_equal(rh.mask, rr.mask)
                    np.testing.assert_array_equal(rp.mask, rr.mask)
                    for k in rh.metrics:
                        assert abs(rh.metrics[k] - rr.metrics[k]) < 1e-4
                    np.testing.assert_allclose(rh.wallclock_s,
                                               rr.wallclock_s, rtol=1e-5)
                for a, b in zip(leaves(th), leaves(tr)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=1e-4)
                for a, b in zip(leaves(tp), leaves(tr)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=1e-4)
                print(f"ring matrix OK algorithm={algorithm} bits={bits}")

            # no-survivor on the mesh: ring + dropout=1.0 freezes disc
            for avg_impl in ("pallas", "ring"):
                tr = make("fused", "mesh", 16, "proposed",
                          avg_impl=avg_impl,
                          faults=FaultConfig(n_devices=K,
                                             dropout_prob=1.0))
                disc0 = jax.tree.map(np.asarray, tr.state["disc"])
                hist = tr.run(3)
                assert all(not rec.mask.any() for rec in hist)
                for a, f in zip(
                        jax.tree_util.tree_leaves(tr.state["disc"]),
                        jax.tree_util.tree_leaves(disc0)):
                    np.testing.assert_array_equal(np.asarray(a), f)
                print(f"mesh no-survivor OK avg_impl={avg_impl}")
        """)


class TestDriverSelection:
    """Regression for the silent driver coercion fixed in PR 2:
    requesting the fused driver for an unsupported algorithm raises."""

    def test_fused_centralized_raises(self):
        with pytest.raises(ValueError, match="fused"):
            make_trainer("fused", algorithm="centralized")

    def test_auto_resolves_per_algorithm(self):
        assert make_trainer("auto").driver == "fused"
        assert make_trainer("auto", algorithm="fedgan").driver == "fused"
        assert make_trainer("auto",
                            algorithm="centralized").driver == "host"

    def test_explicit_host_always_allowed(self):
        assert make_trainer("host", algorithm="centralized").driver == "host"

    def test_unknown_driver_raises(self):
        with pytest.raises(ValueError):
            make_trainer("warp")


class TestSchedulerTwinParity:
    """Satellite (b): each JAX policy selects the same device sets as its
    numpy twin under identical rates."""

    @pytest.mark.parametrize("policy", ["all", "round_robin",
                                        "best_channel", "prop_fair"])
    def test_policy_matches_numpy_twin(self, policy):
        k, ratio, rounds = 5, 0.4, 12          # n=2: cursor wraps at 5
        rng = np.random.default_rng(11)
        np_state = SchedulerState(policy, k, ratio=ratio)
        jx = JaxScheduler(policy=policy, n_devices=k, ratio=ratio)
        carry = jx.init_carry()
        assert jx.n_scheduled == np_state.n_scheduled
        for t in range(rounds):
            rates = rng.uniform(0.5, 10.0, k)   # distinct w.p. 1
            np_mask = schedule_round(np_state, rates, rng)
            jx_mask, carry = schedule_step(
                jx, carry, jnp.asarray(rates, jnp.float32),
                jax.random.fold_in(KEY, t))
            np.testing.assert_array_equal(np_mask, np.asarray(jx_mask))
            np.testing.assert_allclose(np.asarray(carry["ewma_rate"]),
                                       np_state.ewma_rate, rtol=1e-5)
        if policy == "round_robin":
            # 12 rounds x n=2 -> cursor 24 % 5 == 4 in both twins
            assert int(carry["rr_cursor"]) == np_state.rr_cursor == 4

    def test_prop_fair_ewma_drives_rotation(self):
        """Served devices' EWMA rises, shifting priority to unserved
        ones — the numpy twin's rotation property, on the JAX side."""
        jx = JaxScheduler(policy="prop_fair", n_devices=4, ratio=0.5)
        carry = jx.init_carry()
        rates = jnp.ones(4)
        m1, carry = schedule_step(jx, carry, rates, KEY)
        m2, carry = schedule_step(jx, carry, rates, KEY)
        assert (np.asarray(m1) != np.asarray(m2)).any()

    def test_random_policy_counts_and_coverage(self):
        """`random` matches in distribution: always exactly n scheduled,
        every device selected eventually."""
        jx = JaxScheduler(policy="random", n_devices=6, ratio=0.34)
        carry = jx.init_carry()
        seen = np.zeros(6, dtype=bool)
        for t in range(60):
            mask, carry = schedule_step(jx, carry, jnp.ones(6),
                                        jax.random.fold_in(KEY, t))
            mask = np.asarray(mask)
            assert mask.sum() == jx.n_scheduled
            seen |= mask
        assert seen.all()

    def test_unknown_policy_raises(self):
        jx = JaxScheduler(policy="nope", n_devices=4)
        with pytest.raises(ValueError):
            schedule_step(jx, jx.init_carry(), jnp.ones(4), KEY)


class TestChannelTwinParity:
    def _pair(self, **kw):
        cfg = ChannelConfig(n_devices=6, seed=3, **kw)
        return ChannelSimulator(cfg), JaxChannel(cfg)

    def test_placement_and_static_rates_match(self):
        np_sim, jx_sim = self._pair(fading=False)
        np.testing.assert_allclose(np.asarray(jx_sim.dist_km),
                                   np_sim.dist_km, rtol=1e-6)
        for n_sched in (1, 3, 6):
            np.testing.assert_allclose(
                np.asarray(jx_sim.uplink_rates(KEY, n_sched)),
                np_sim.uplink_rates(n_sched), rtol=1e-5)
        np.testing.assert_allclose(jx_sim.downlink_rate_s,
                                   np_sim.downlink_rate(), rtol=1e-6)

    @pytest.mark.parametrize("schedule,fedgan", [("serial", False),
                                                 ("parallel", False),
                                                 ("serial", True)])
    def test_round_timing_and_wallclock_match(self, schedule, fedgan):
        np_sim, jx_sim = self._pair(fading=False)
        mask = np.array([True, True, False, True, False, True])
        kw = dict(disc_params=10_000, gen_params=12_000,
                  disc_step_flops=1e9, gen_step_flops=1e9, n_d=2, n_g=2,
                  fedgan=fedgan)
        t_np = np_sim.round_timing(mask=mask, **kw)
        t_jx = jx_sim.round_timing(KEY, jnp.asarray(mask), **kw)
        np.testing.assert_allclose(np.asarray(t_jx.upload_s), t_np.upload_s,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(t_jx.compute_dev_s),
                                   t_np.compute_dev_s, rtol=1e-5)
        np.testing.assert_allclose(float(t_jx.compute_srv_s),
                                   t_np.compute_srv_s, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(t_jx.stragglers),
                                      t_np.stragglers)
        w_np = round_wallclock(t_np, mask, schedule=schedule, fedgan=fedgan)
        w_jx = jax_round_wallclock(t_jx, jnp.asarray(mask),
                                   schedule=schedule, fedgan=fedgan)
        np.testing.assert_allclose(float(w_jx), w_np, rtol=1e-5)

    def test_all_stragglers_falls_back_to_broadcast(self):
        np_sim, jx_sim = self._pair(fading=False,
                                    straggler_deadline_s=1e-12)
        mask = np.ones(6, dtype=bool)
        kw = dict(disc_params=10_000, gen_params=12_000,
                  disc_step_flops=1e9, gen_step_flops=1e9, n_d=2, n_g=2)
        t_np = np_sim.round_timing(mask=mask, **kw)
        t_jx = jx_sim.round_timing(KEY, jnp.asarray(mask), **kw)
        assert np.asarray(t_jx.stragglers).all()
        w_np = round_wallclock(t_np, mask, schedule="serial")
        w_jx = jax_round_wallclock(t_jx, jnp.asarray(mask),
                                   schedule="serial")
        np.testing.assert_allclose(float(w_jx), w_np, rtol=1e-5)
        np.testing.assert_allclose(float(w_jx), t_np.broadcast_s, rtol=1e-5)

    def test_fading_rates_match_in_distribution(self):
        """jax.random vs numpy Exp(1) streams: per-device mean uplink
        rate over many draws agrees (the twins share every deterministic
        factor, so only the fading marginal is being compared)."""
        np_sim, jx_sim = self._pair(fading=True)
        n = 2000
        np_rates = np.stack([np_sim.uplink_rates(3) for _ in range(n)])
        keys = jax.random.split(jax.random.PRNGKey(42), n)
        jx_rates = np.asarray(
            jax.vmap(lambda k: jx_sim.uplink_rates(k, 3))(keys))
        np.testing.assert_allclose(jx_rates.mean(0), np_rates.mean(0),
                                   rtol=0.1)
        np.testing.assert_allclose(jx_rates.std(0), np_rates.std(0),
                                   rtol=0.15)


class TestGanRoundsScanApi:
    def test_scan_returns_stacked_outputs(self):
        pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4)
        state = protocol.make_train_state(
            KEY, lambda k: dcgan.gan_init(k, CFG), pcfg, K)
        chan_cfg = ChannelConfig(n_devices=K, seed=3)
        state, carry, out = protocol.gan_rounds_scan(
            SPEC, pcfg, state, DATA, KEY, 3,
            channel=JaxChannel(chan_cfg),
            scheduler=JaxScheduler(policy="all", n_devices=K))
        assert out["wallclock_s"].shape == (3,)
        assert out["mask"].shape == (3, K) and out["mask"].dtype == bool
        assert out["weights"].shape == (3, K)
        for v in out["metrics"].values():
            assert v.shape == (3,)
        assert set(carry) == {"rr_cursor", "ewma_rate"}
        for leaf in jax.tree_util.tree_leaves(state):
            assert bool(jnp.isfinite(leaf).all())

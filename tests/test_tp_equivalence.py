"""Tensor-parallel mesh slices: the 2-D (device x model) shard_map
engine must preserve the paper's Algorithm 1/2 semantics on the device
axis EXACTLY while Megatron-sharding the model axis inside each worker
slice.

Contract (ISSUE 5 acceptance; see core/shard_round.py docstring):

  * tp=2 mesh-fused matches tp=1 mesh-fused AND the host oracle for
    BOTH algorithms, over schedules x quantize-bits, on a forced
    16-device host (8 data x 2 model): scheduling masks BITWISE, params
    to f32 round-off. TP may only change matmul reduction order — the
    uplink quantizer reconstructs the worker-global stream per shard
    (quantize.roundtrip_tp), so quantization itself is bitwise-stable
    across TP widths.
  * tp=1 takes the exact pre-TP code paths (tp_axis=None throughout) —
    pinned by the existing 8-device mesh matrix staying green.
  * Checkpoints are GLOBAL-shaped at every tp (shard_map splits and
    reassembles), so resume works across TP widths.

The model is `models.gan.mlp_gan_spec` — the same two-layer MLP-GAN
`benchmarks/driver_bench.py` measures — whose w_in/w_out leaves carry
the column/row-parallel name rules of `sharding.rules.tp_leaf_dim`.
Runs in CI's mesh-tp lane (16 forced host devices).
"""
import pytest

from conftest import run_on_host_mesh

# Params tolerance: f32 matmul-reduction round-off, amplified at 16-bit
# quantization by at most one stochastic-rounding flip per element
# (one quantum ~ absmax / 32767).
_TP_MATRIX = """
    import itertools, tempfile
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ProtocolConfig
    from repro.core import Trainer
    from repro.core.channel import ChannelConfig
    from repro.models.gan import mlp_gan_init, mlp_gan_spec

    KEY = jax.random.PRNGKey(0)
    K, NZ, HIDDEN, DIM = 8, 8, 16, 64
    DATA = jax.random.normal(jax.random.PRNGKey(9), (K, 8, DIM))
    SPEC = {1: mlp_gan_spec(d_z=NZ, tp_axis=None),
            2: mlp_gan_spec(d_z=NZ, tp_axis="model")}

    def make(driver, layout, schedule, bits, algorithm, tp=1):
        pcfg = ProtocolConfig(
            n_devices=K, n_d=1, n_g=1, sample_size=4,
            server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
            schedule=schedule, scheduler="round_robin",
            scheduling_ratio=0.5, quantize_bits=bits)
        chan = ChannelConfig(n_devices=K, seed=3, fading=False)
        return Trainer(SPEC[tp], pcfg,
                       lambda k: mlp_gan_init(k, d_z=NZ, d_hidden=HIDDEN,
                                              d_data=DIM),
                       DATA, KEY, channel_cfg=chan, driver=driver,
                       layout=layout, algorithm=algorithm, tp=tp)

    def leaves(t):
        return jax.tree_util.tree_leaves(t.state)

    for algorithm, schedule, bits in itertools.product(
            ("proposed", "fedgan"), ("serial", "parallel"), (16, 32)):
        th = make("host", "stacked", schedule, bits, algorithm)
        t1 = make("fused", "mesh", schedule, bits, algorithm, tp=1)
        t2 = make("fused", "mesh", schedule, bits, algorithm, tp=2)
        h, m1, m2 = th.run(4), t1.run(4), t2.run(4)
        for rh, r1, r2 in zip(h, m1, m2):
            np.testing.assert_array_equal(rh.mask, r1.mask)
            np.testing.assert_array_equal(rh.mask, r2.mask)   # bitwise
            for k in rh.metrics:
                assert abs(rh.metrics[k] - r2.metrics[k]) < 1e-4, \\
                    (rh.round, k, rh.metrics[k], r2.metrics[k])
            np.testing.assert_allclose(rh.wallclock_s, r2.wallclock_s,
                                       rtol=1e-5)
        atol = 5e-5 if bits < 32 else 2e-5
        for a, b in zip(leaves(t1), leaves(t2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=atol)
        for a, b in zip(leaves(th), leaves(t2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=atol)
        print(f"tp matrix OK algorithm={algorithm} "
              f"schedule={schedule} bits={bits}")

    # per-round mesh dispatch (host driver) agrees at tp=2 too — one
    # representative per algorithm
    for algorithm in ("proposed", "fedgan"):
        th = make("host", "stacked", "serial", 16, algorithm)
        tm = make("host", "mesh", "serial", 16, algorithm, tp=2)
        h, m = th.run(3), tm.run(3)
        for rh, rm in zip(h, m):
            np.testing.assert_array_equal(rh.mask, rm.mask)
        for a, b in zip(leaves(th), leaves(tm)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-5)
        print(f"tp mesh host driver OK algorithm={algorithm}")

    # tp=2 resume continues masks, params, and the wallclock curve
    # exactly; and a tp=1 checkpoint restores into a tp=2 trainer
    # (checkpoints are GLOBAL-shaped at every tp)
    for algorithm in ("proposed", "fedgan"):
        d = tempfile.mkdtemp()
        ta = make("fused", "mesh", "serial", 16, algorithm, tp=2)
        ta.run(2)
        ta.save_checkpoint(d)
        tb = make("fused", "mesh", "serial", 16, algorithm, tp=2)
        tb.restore(d)
        tb.run(2)
        tc = make("fused", "mesh", "serial", 16, algorithm, tp=2)
        tc.run(4)
        for a, b in zip(leaves(tb), leaves(tc)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert tb._clock == tc._clock
        print(f"tp=2 resume OK algorithm={algorithm}")

    d = tempfile.mkdtemp()
    t1 = make("fused", "mesh", "serial", 16, "proposed", tp=1)
    t1.run(2)
    t1.save_checkpoint(d)
    t2 = make("fused", "mesh", "serial", 16, "proposed", tp=2)
    t2.restore(d)
    t2.run(2)
    t1.run(2)
    for a, b in zip(leaves(t1), leaves(t2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-5)
    print("cross-tp restore OK (tp=1 checkpoint -> tp=2 run)")
"""


@pytest.mark.slow
def test_tp2_matches_tp1_and_host_oracle_on_16_device_mesh():
    """The FULL tp matrix in ONE 16-device subprocess (jax startup
    dominates): both algorithms x schedules x bits, the per-round tp=2
    oracle, tp=2 resume, and the cross-tp checkpoint restore."""
    run_on_host_mesh(_TP_MATRIX, n_devices=16)


class TestTpValidation:
    """Fast-lane construction guards (no multi-device mesh needed)."""

    def test_tp_requires_mesh_layout(self):
        import jax
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        data = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        with pytest.raises(ValueError, match="mesh"):
            Trainer(mlp_gan_spec(), ProtocolConfig(n_devices=4),
                    mlp_gan_init, data, jax.random.PRNGKey(0),
                    layout="stacked", tp=2)

    def test_tp_zero_rejected(self):
        import jax
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        data = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 64))
        with pytest.raises(ValueError, match="tp"):
            Trainer(mlp_gan_spec(), ProtocolConfig(n_devices=4),
                    mlp_gan_init, data, jax.random.PRNGKey(0),
                    layout="mesh", tp=0)

    def test_mesh_without_model_axis_rejected_for_tp(self):
        import jax
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.launch.mesh import make_mesh
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        mesh = make_mesh((1,), ("data",))
        data = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 64))
        with pytest.raises(ValueError, match="model"):
            Trainer(mlp_gan_spec(tp_axis="model"),
                    ProtocolConfig(n_devices=1), mlp_gan_init, data,
                    jax.random.PRNGKey(0), layout="mesh", tp=2,
                    mesh=mesh)

    def test_dense_spec_rejected_at_tp2(self):
        """A spec without in-slice collectives consumes shards
        shape-consistently but never psums — the engine must refuse the
        mismatch instead of training silently wrong."""
        import jax
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        data = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
        with pytest.raises(ValueError, match="tp_axis"):
            Trainer(mlp_gan_spec(tp_axis=None),
                    ProtocolConfig(n_devices=2), mlp_gan_init, data,
                    jax.random.PRNGKey(0), layout="mesh", tp=2)

    def test_tp_spec_rejected_on_mesh_tp1_and_stacked(self):
        import jax
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        data = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 64))
        for kw in (dict(layout="mesh", tp=1), dict(layout="stacked")):
            with pytest.raises(ValueError, match="tp_axis"):
                Trainer(mlp_gan_spec(tp_axis="model"),
                        ProtocolConfig(n_devices=1), mlp_gan_init, data,
                        jax.random.PRNGKey(0), **kw)

    def test_moe_backbone_rejects_tp(self):
        """MoE experts reuse the mlp leaf names but moe_apply has no
        in-slice collectives — the spec builder refuses TP for MoE
        configs, and the rules replicate everything under `experts`."""
        from repro.configs import get_arch_config
        from repro.models.specs import make_backbone_spec
        cfg = get_arch_config("mixtral-8x22b").reduced()
        with pytest.raises(ValueError, match="MoE"):
            make_backbone_spec(cfg, 16, tp_axis="model")

    def test_in_scan_fid_rejected_under_tp(self):
        """The in-slice generator is a shard under TP, so in-scan FID
        must refuse instead of silently evaluating a shard."""
        import jax
        from repro.configs.base import ProtocolConfig
        from repro.core import shard_round
        from repro.core.channel import ChannelConfig
        from repro.core.jax_channel import JaxChannel
        from repro.core.jax_scheduling import JaxScheduler
        from repro.launch.mesh import make_host_mesh
        from repro.models.gan import mlp_gan_spec
        with pytest.raises(NotImplementedError, match="FID"):
            shard_round.shard_rounds_scan(
                mlp_gan_spec(tp_axis="model"),
                ProtocolConfig(n_devices=1), make_host_mesh(1, 1), 2,
                channel=JaxChannel(ChannelConfig(n_devices=1)),
                scheduler=JaxScheduler(policy="all", n_devices=1),
                tp_axis="model", tp=2,
                eval_fn=lambda g, t, k: 0.0, eval_every=2)

    def test_allgather_payload_halves_at_tp2(self):
        """The Algorithm-2 all-gather payload per TP rank is 1/tp of
        the model for the fully-TP-shardable MLP-GAN (the driver_bench
        allgather_bytes_per_rank column's invariant)."""
        import jax
        from repro.models.gan import mlp_gan_init
        from repro.sharding import rules
        state = mlp_gan_init(jax.random.PRNGKey(0))
        full = sum(x.size
                   for x in jax.tree_util.tree_leaves(state["disc"]))
        assert rules.tp_local_size(state["disc"], 2) * 2 == full
        two_net = {"gen": state["gen"], "disc": state["disc"]}
        full2 = sum(x.size for x in jax.tree_util.tree_leaves(two_net))
        assert rules.tp_local_size(two_net, 2) * 2 == full2

"""Sharding rules: divisibility, role assignment, cache specs.

These run against abstract shapes + a 1x1 host mesh (no XLA_FLAGS)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch_config
from repro.configs.base import MeshConfig
from repro.launch.mesh import make_host_mesh
from repro.models import gan
from repro.models.backbone import init_decode_caches
from repro.sharding import rules

KEY = jax.random.PRNGKey(0)


class FakeMesh:
    """Just enough mesh for the rules (shape lookups)."""
    def __init__(self, data=16, model=16, pod=None):
        self.shape = {"data": data, "model": model}
        if pod:
            self.shape["pod"] = pod


def test_plan_fsdp_threshold():
    small = get_arch_config("granite-3-2b")
    big = get_arch_config("mixtral-8x22b")
    assert rules.plan_for(small, MeshConfig()).fsdp_axes is None
    assert rules.plan_for(big, MeshConfig()).fsdp_axes == ("data",)
    assert rules.plan_for(big, MeshConfig(multi_pod=True)).fsdp_axes == \
        ("pod", "data")


def test_param_specs_roles():
    cfg = get_arch_config("qwen3-1.7b").reduced()
    params = jax.eval_shape(lambda: gan.generator_init(KEY, cfg))
    mesh = FakeMesh(data=2, model=4)
    plan = rules.ParallelismPlan(fsdp_axes=("data",), dev_axes=("data",))
    specs = rules.param_specs(params, mesh, plan, fsdp=True)
    # embedding: d over model (vocab 512 % 4 == 0 but rule shards d)
    assert specs["embed"]["table"] == P(None, "model")
    # in-projection: (d, out) -> (fsdp, tp); leading group axis unsharded
    wq = specs["backbone"]["groups"]["sub0"]["attn"]["wq"]
    assert wq == P(None, "data", "model")
    # out-projection: (in, d) -> (tp, fsdp)
    wo = specs["backbone"]["groups"]["sub0"]["attn"]["wo"]
    assert wo == P(None, "model", "data")
    # norms replicated
    assert specs["backbone"]["final_norm"]["scale"] == P()


def test_param_specs_skip_indivisible():
    cfg = get_arch_config("granite-3-2b")   # vocab 49155 is odd
    params = jax.eval_shape(
        lambda: {"embed": {"table": jnp.zeros((cfg.vocab, 8))}})
    mesh = FakeMesh(data=16, model=16)
    plan = rules.ParallelismPlan(dev_axes=("data",))
    specs = rules.param_specs(params, mesh, plan)
    # d=8 not divisible by 16 either -> fully replicated, never crashes
    assert specs["embed"]["table"] == P(None, None)


def test_cache_specs_batch_vs_seq():
    cfg = get_arch_config("granite-3-2b").reduced()
    caches = jax.eval_shape(lambda: init_decode_caches(cfg, 32, 64))
    mesh = FakeMesh(data=16, model=16)
    plan = rules.ParallelismPlan(dev_axes=("data",))
    # batch 32 divisible by 16 -> batch-sharded
    specs = rules.cache_specs(cfg, caches, 32, mesh, plan)
    k_spec = specs["sub0"]["k"]
    assert k_spec[1] == "data"
    # batch 1 -> sequence-sharded over (data, model)
    caches1 = jax.eval_shape(lambda: init_decode_caches(cfg, 1, 512))
    specs1 = rules.cache_specs(cfg, caches1, 1, mesh, plan)
    assert specs1["sub0"]["k"][2] == ("data", "model")


def test_state_specs_cover_train_state():
    from repro.configs.base import ProtocolConfig
    from repro.core import protocol
    cfg = get_arch_config("mamba2-130m").reduced()
    pcfg = ProtocolConfig(n_devices=4)
    state = jax.eval_shape(lambda: protocol.make_train_state(
        KEY, lambda k: gan.gan_init(k, cfg), pcfg, 4))
    mesh = FakeMesh(data=4, model=2)
    plan = rules.ParallelismPlan(dev_axes=("data",))
    specs = rules.state_specs(state, mesh, plan, gen_fsdp=False)
    # structure must match exactly (same treedef)
    jax.tree.map(lambda a, b: None, state, specs,
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))

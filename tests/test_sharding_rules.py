"""Sharding rules: divisibility, role assignment, cache specs.

These run against abstract shapes + a 1x1 host mesh (no XLA_FLAGS)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch_config
from repro.configs.base import MeshConfig
from repro.launch.mesh import make_host_mesh
from repro.models import gan
from repro.models.backbone import init_decode_caches
from repro.sharding import rules

KEY = jax.random.PRNGKey(0)


class FakeMesh:
    """Just enough mesh for the rules (shape lookups)."""
    def __init__(self, data=16, model=16, pod=None):
        self.shape = {"data": data, "model": model}
        if pod:
            self.shape["pod"] = pod


def test_plan_fsdp_threshold():
    small = get_arch_config("granite-3-2b")
    big = get_arch_config("mixtral-8x22b")
    assert rules.plan_for(small, MeshConfig()).fsdp_axes is None
    assert rules.plan_for(big, MeshConfig()).fsdp_axes == ("data",)
    assert rules.plan_for(big, MeshConfig(multi_pod=True)).fsdp_axes == \
        ("pod", "data")


def test_param_specs_roles():
    cfg = get_arch_config("qwen3-1.7b").reduced()
    params = jax.eval_shape(lambda: gan.generator_init(KEY, cfg))
    mesh = FakeMesh(data=2, model=4)
    plan = rules.ParallelismPlan(fsdp_axes=("data",), dev_axes=("data",))
    specs = rules.param_specs(params, mesh, plan, fsdp=True)
    # embedding: d over model (vocab 512 % 4 == 0 but rule shards d)
    assert specs["embed"]["table"] == P(None, "model")
    # in-projection: (d, out) -> (fsdp, tp); leading group axis unsharded
    wq = specs["backbone"]["groups"]["sub0"]["attn"]["wq"]
    assert wq == P(None, "data", "model")
    # out-projection: (in, d) -> (tp, fsdp)
    wo = specs["backbone"]["groups"]["sub0"]["attn"]["wo"]
    assert wo == P(None, "model", "data")
    # norms replicated
    assert specs["backbone"]["final_norm"]["scale"] == P()


def test_param_specs_skip_indivisible():
    cfg = get_arch_config("granite-3-2b")   # vocab 49155 is odd
    params = jax.eval_shape(
        lambda: {"embed": {"table": jnp.zeros((cfg.vocab, 8))}})
    mesh = FakeMesh(data=16, model=16)
    plan = rules.ParallelismPlan(dev_axes=("data",))
    specs = rules.param_specs(params, mesh, plan)
    # d=8 not divisible by 16 either -> fully replicated, never crashes
    assert specs["embed"]["table"] == P(None, None)


def test_cache_specs_batch_vs_seq():
    cfg = get_arch_config("granite-3-2b").reduced()
    caches = jax.eval_shape(lambda: init_decode_caches(cfg, 32, 64))
    mesh = FakeMesh(data=16, model=16)
    plan = rules.ParallelismPlan(dev_axes=("data",))
    # batch 32 divisible by 16 -> batch-sharded
    specs = rules.cache_specs(cfg, caches, 32, mesh, plan)
    k_spec = specs["sub0"]["k"]
    assert k_spec[1] == "data"
    # batch 1 -> sequence-sharded over (data, model)
    caches1 = jax.eval_shape(lambda: init_decode_caches(cfg, 1, 512))
    specs1 = rules.cache_specs(cfg, caches1, 1, mesh, plan)
    assert specs1["sub0"]["k"][2] == ("data", "model")


def test_state_specs_cover_train_state():
    from repro.configs.base import ProtocolConfig
    from repro.core import protocol
    cfg = get_arch_config("mamba2-130m").reduced()
    pcfg = ProtocolConfig(n_devices=4)
    state = jax.eval_shape(lambda: protocol.make_train_state(
        KEY, lambda k: gan.gan_init(k, cfg), pcfg, 4))
    mesh = FakeMesh(data=4, model=2)
    plan = rules.ParallelismPlan(dev_axes=("data",))
    specs = rules.state_specs(state, mesh, plan, gen_fsdp=False)
    # structure must match exactly (same treedef)
    jax.tree.map(lambda a, b: None, state, specs,
                 is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


# ---------------------------------------------------------------------------
# In-slice tensor parallelism (the mesh layout's model axis)
# ---------------------------------------------------------------------------

class TestTpRules:
    def test_tp_leaf_dim_roles(self):
        assert rules.tp_leaf_dim("w_in", (8, 16), 2) == -1
        assert rules.tp_leaf_dim("w_gate", (8, 16), 2) == -1
        assert rules.tp_leaf_dim("b_in", (16,), 2) == -1
        assert rules.tp_leaf_dim("w_out", (16, 8), 2) == -2
        assert rules.tp_leaf_dim("table", (100, 16), 2) is None
        assert rules.tp_leaf_dim("wq", (16, 16), 2) is None

    def test_tp_leaf_dim_indivisible_raises(self):
        """The manual Megatron path psums unconditionally, so a
        replication fallback would inflate outputs by exactly tp —
        indivisible TP-named dims must be a hard error."""
        with pytest.raises(ValueError, match="divisible"):
            rules.tp_leaf_dim("w_in", (8, 15), 2)
        with pytest.raises(ValueError, match="divisible"):
            rules.tp_leaf_dim("w_out", (15, 8), 2)
        assert rules.tp_leaf_dim("w_in", (8, 16), 1) is None  # tp=1 ok
        # non-TP names never raise, whatever their shape
        assert rules.tp_leaf_dim("wq", (8, 15), 2) is None

    def test_tp_tree_dims_aligned_and_stacked_safe(self):
        tree = {"w_in": jnp.zeros((8, 16)), "w_out": jnp.zeros((16, 8)),
                "ln": jnp.zeros((8,))}
        dims = rules.tp_tree_dims(tree, 2)
        flat_names = [p[-1].key for p, _ in
                      jax.tree_util.tree_flatten_with_path(tree)[0]]
        got = dict(zip(flat_names, dims))
        assert got == {"w_in": -1, "w_out": -2, "ln": None}
        # negative dims survive a leading stacked K axis unchanged
        stacked = jax.tree.map(lambda x: jnp.zeros((4,) + x.shape), tree)
        assert rules.tp_tree_dims(stacked, 2) == dims

    def test_tp_local_size(self):
        tree = {"w_in": jnp.zeros((8, 16)), "ln": jnp.zeros((10,))}
        assert rules.tp_local_size(tree, 2) == 8 * 16 // 2 + 10
        assert rules.tp_local_size(tree, 1) == 8 * 16 + 10

    def test_shard_round_state_specs_tp(self):
        state = {
            "disc": {"w_in": jnp.zeros((8, 16)), "ln": jnp.zeros((8,))},
            "disc_opt": {"m": {"w_out": jnp.zeros((4, 16, 8))},
                         "t": jnp.zeros((4,))},
        }
        specs = rules.shard_round_state_specs(
            state, ("data",), stacked_keys=("disc_opt",),
            tp_axis="model", tp=2)
        assert specs["disc"]["w_in"] == P(None, "model")
        assert specs["disc"]["ln"] == P()
        # stacked opt moment: data on the K axis, model on the TP dim
        # (trailing None trimmed — P(None) != P() on jax 0.4.x)
        assert specs["disc_opt"]["m"]["w_out"] == P("data", "model")
        assert specs["disc_opt"]["t"] == P("data")

    def test_shard_round_state_specs_tp1_unchanged(self):
        state = {"disc": {"w_in": jnp.zeros((8, 16))},
                 "disc_opt": {"w_in": jnp.zeros((4, 8, 16))}}
        a = rules.shard_round_state_specs(state, ("data",))
        b = rules.shard_round_state_specs(state, ("data",),
                                          tp_axis=None, tp=1)
        assert a == b
        assert a["disc"]["w_in"] == P()
        # legacy tp=1 form: the device-axes TUPLE in position 0
        assert a["disc_opt"]["w_in"] == P(("data",))

    def test_expert_subtrees_always_replicate(self):
        """MoE experts reuse mlp leaf names but moe_apply has no TP
        collectives — anything under an `experts` subtree must stay
        replicated, whatever its leaf name."""
        tree = {"ff": {"router": jnp.zeros((8, 4)),
                       "experts": {"w_in": jnp.zeros((4, 8, 16)),
                                   "w_gate": jnp.zeros((4, 8, 16)),
                                   "w_out": jnp.zeros((4, 16, 8))}},
                "w_in": jnp.zeros((8, 16))}
        dims = rules.tp_tree_dims(tree, 2)
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        got = {"/".join(p.key for p in path): d
               for (path, _), d in zip(flat, dims)}
        assert got["ff/experts/w_in"] is None
        assert got["ff/experts/w_gate"] is None
        assert got["ff/experts/w_out"] is None
        assert got["w_in"] == -1        # non-expert mlp leaf still shards
        specs = rules.shard_round_state_specs(
            {"disc": tree}, ("data",), stacked_keys=(),
            tp_axis="model", tp=2)
        assert specs["disc"]["ff"]["experts"]["w_in"] == P()
        assert specs["disc"]["w_in"] == P(None, "model")

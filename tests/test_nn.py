import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.nn.attention import build_mask, NEG_INF


KEY = jax.random.PRNGKey(0)


class TestLinearNorms:
    def test_linear_shapes_bias(self):
        p = nn.linear_init(KEY, 8, 12)
        y = nn.linear_apply(p, jnp.ones((3, 8)))
        assert y.shape == (3, 12)

    def test_rmsnorm_unit_scale(self):
        p = nn.rmsnorm_init(16)
        x = jax.random.normal(KEY, (4, 16)) * 10
        y = nn.rmsnorm_apply(p, x)
        rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_layernorm_zero_mean(self):
        p = nn.layernorm_init(16)
        x = jax.random.normal(KEY, (4, 16)) + 3.0
        y = nn.layernorm_apply(p, x)
        np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)

    def test_batchnorm_stats(self):
        p = nn.batchnorm_init(3)
        x = jax.random.normal(KEY, (8, 4, 4, 3)) * 5 + 2
        y = nn.batchnorm_apply(p, x)
        np.testing.assert_allclose(y.mean((0, 1, 2)), 0.0, atol=1e-4)
        np.testing.assert_allclose(y.std((0, 1, 2)), 1.0, atol=1e-2)

    def test_norm_dtype_preserved(self):
        p = nn.rmsnorm_init(8)
        y = nn.rmsnorm_apply(p, jnp.ones((2, 8), dtype=jnp.bfloat16))
        assert y.dtype == jnp.bfloat16


class TestRoPE:
    def test_rope_preserves_norm(self):
        inv = nn.rope_frequencies(8)
        x = jax.random.normal(KEY, (2, 5, 3, 8))
        pos = jnp.broadcast_to(jnp.arange(5), (2, 5))
        y = nn.apply_rope(x, pos, inv)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
            rtol=1e-5)

    def test_rope_relative_shift(self):
        """Rotating q and k by the same offset keeps their dot product."""
        inv = nn.rope_frequencies(16)
        q = jax.random.normal(KEY, (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
        def dot_at(pq, pk):
            qq = nn.apply_rope(q, jnp.full((1, 1), pq), inv)
            kk = nn.apply_rope(k, jnp.full((1, 1), pk), inv)
            return float(jnp.sum(qq * kk))
        assert dot_at(3, 1) == pytest.approx(dot_at(13, 11), rel=1e-4)


class TestMasks:
    def test_causal(self):
        pos = jnp.arange(4)[None]
        m = build_mask(pos, pos, causal=True, window=None)
        expect = np.triu(np.full((4, 4), NEG_INF), k=1)
        np.testing.assert_allclose(m[0], expect)

    def test_window(self):
        pos = jnp.arange(6)[None]
        m = build_mask(pos, pos, causal=True, window=2)
        allowed = np.asarray(m[0] == 0)
        for i in range(6):
            for j in range(6):
                assert allowed[i, j] == (j <= i and j > i - 2)

    def test_k_valid(self):
        qpos = jnp.arange(3)[None]
        kpos = jnp.arange(3)[None]
        valid = jnp.asarray([[True, False, True]])
        m = build_mask(qpos, kpos, causal=False, window=None, k_valid=valid)
        assert (np.asarray(m[0][:, 1]) == NEG_INF).all()


class TestAttention:
    def test_gqa_shapes(self):
        p = nn.attention_init(KEY, 32, 8, 2)
        y = nn.attention_apply(p, jnp.ones((2, 6, 32)), n_heads=8,
                               n_kv_heads=2)
        assert y.shape == (2, 6, 32)

    def test_causality(self):
        """Changing a future token must not change earlier outputs."""
        p = nn.attention_init(KEY, 32, 4, 4)
        x = jax.random.normal(KEY, (1, 8, 32))
        y1 = nn.attention_apply(p, x, n_heads=4, n_kv_heads=4)
        x2 = x.at[:, -1].add(10.0)
        y2 = nn.attention_apply(p, x2, n_heads=4, n_kv_heads=4)
        np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], atol=1e-5)

    def test_qk_norm_finite_large_inputs(self):
        p = nn.attention_init(KEY, 32, 4, 2, qk_norm=True)
        x = jax.random.normal(KEY, (1, 8, 32)) * 1e3
        y = nn.attention_apply(p, x, n_heads=4, n_kv_heads=2, qk_norm=True)
        assert jnp.isfinite(y).all()


class TestMLPConv:
    def test_swiglu(self):
        p = nn.mlp_init(KEY, 16, 32)
        assert nn.mlp_apply(p, jnp.ones((2, 16))).shape == (2, 16)
        assert "w_gate" in p

    def test_gelu_bias(self):
        p = nn.mlp_init(KEY, 16, 32, gated=False, use_bias=True)
        assert "w_gate" not in p and "b_in" in p
        assert nn.mlp_apply(p, jnp.ones((2, 16))).shape == (2, 16)

    def test_conv_updown(self):
        pc = nn.conv2d_init(KEY, 3, 8, 4)
        pt = nn.conv_transpose2d_init(KEY, 8, 3, 4)
        img = jax.random.normal(KEY, (2, 16, 16, 3))
        down = nn.conv2d_apply(pc, img)
        assert down.shape == (2, 8, 8, 8)
        up = nn.conv_transpose2d_apply(pt, down)
        assert up.shape == (2, 16, 16, 3)

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.nn.attention import build_mask, NEG_INF


KEY = jax.random.PRNGKey(0)


class TestLinearNorms:
    def test_linear_shapes_bias(self):
        p = nn.linear_init(KEY, 8, 12)
        y = nn.linear_apply(p, jnp.ones((3, 8)))
        assert y.shape == (3, 12)

    def test_rmsnorm_unit_scale(self):
        p = nn.rmsnorm_init(16)
        x = jax.random.normal(KEY, (4, 16)) * 10
        y = nn.rmsnorm_apply(p, x)
        rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_layernorm_zero_mean(self):
        p = nn.layernorm_init(16)
        x = jax.random.normal(KEY, (4, 16)) + 3.0
        y = nn.layernorm_apply(p, x)
        np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-4)

    def test_batchnorm_stats(self):
        p = nn.batchnorm_init(3)
        x = jax.random.normal(KEY, (8, 4, 4, 3)) * 5 + 2
        y = nn.batchnorm_apply(p, x)
        np.testing.assert_allclose(y.mean((0, 1, 2)), 0.0, atol=1e-4)
        np.testing.assert_allclose(y.std((0, 1, 2)), 1.0, atol=1e-2)

    def test_norm_dtype_preserved(self):
        p = nn.rmsnorm_init(8)
        y = nn.rmsnorm_apply(p, jnp.ones((2, 8), dtype=jnp.bfloat16))
        assert y.dtype == jnp.bfloat16


class TestRoPE:
    def test_rope_preserves_norm(self):
        inv = nn.rope_frequencies(8)
        x = jax.random.normal(KEY, (2, 5, 3, 8))
        pos = jnp.broadcast_to(jnp.arange(5), (2, 5))
        y = nn.apply_rope(x, pos, inv)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
            rtol=1e-5)

    def test_rope_relative_shift(self):
        """Rotating q and k by the same offset keeps their dot product."""
        inv = nn.rope_frequencies(16)
        q = jax.random.normal(KEY, (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
        def dot_at(pq, pk):
            qq = nn.apply_rope(q, jnp.full((1, 1), pq), inv)
            kk = nn.apply_rope(k, jnp.full((1, 1), pk), inv)
            return float(jnp.sum(qq * kk))
        assert dot_at(3, 1) == pytest.approx(dot_at(13, 11), rel=1e-4)


class TestMasks:
    def test_causal(self):
        pos = jnp.arange(4)[None]
        m = build_mask(pos, pos, causal=True, window=None)
        expect = np.triu(np.full((4, 4), NEG_INF), k=1)
        np.testing.assert_allclose(m[0], expect)

    def test_window(self):
        pos = jnp.arange(6)[None]
        m = build_mask(pos, pos, causal=True, window=2)
        allowed = np.asarray(m[0] == 0)
        for i in range(6):
            for j in range(6):
                assert allowed[i, j] == (j <= i and j > i - 2)

    def test_k_valid(self):
        qpos = jnp.arange(3)[None]
        kpos = jnp.arange(3)[None]
        valid = jnp.asarray([[True, False, True]])
        m = build_mask(qpos, kpos, causal=False, window=None, k_valid=valid)
        assert (np.asarray(m[0][:, 1]) == NEG_INF).all()


class TestAttention:
    def test_gqa_shapes(self):
        p = nn.attention_init(KEY, 32, 8, 2)
        y = nn.attention_apply(p, jnp.ones((2, 6, 32)), n_heads=8,
                               n_kv_heads=2)
        assert y.shape == (2, 6, 32)

    def test_causality(self):
        """Changing a future token must not change earlier outputs."""
        p = nn.attention_init(KEY, 32, 4, 4)
        x = jax.random.normal(KEY, (1, 8, 32))
        y1 = nn.attention_apply(p, x, n_heads=4, n_kv_heads=4)
        x2 = x.at[:, -1].add(10.0)
        y2 = nn.attention_apply(p, x2, n_heads=4, n_kv_heads=4)
        np.testing.assert_allclose(y1[:, :-1], y2[:, :-1], atol=1e-5)

    def test_qk_norm_finite_large_inputs(self):
        p = nn.attention_init(KEY, 32, 4, 2, qk_norm=True)
        x = jax.random.normal(KEY, (1, 8, 32)) * 1e3
        y = nn.attention_apply(p, x, n_heads=4, n_kv_heads=2, qk_norm=True)
        assert jnp.isfinite(y).all()


class TestMLPConv:
    def test_swiglu(self):
        p = nn.mlp_init(KEY, 16, 32)
        assert nn.mlp_apply(p, jnp.ones((2, 16))).shape == (2, 16)
        assert "w_gate" in p

    def test_gelu_bias(self):
        p = nn.mlp_init(KEY, 16, 32, gated=False, use_bias=True)
        assert "w_gate" not in p and "b_in" in p
        assert nn.mlp_apply(p, jnp.ones((2, 16))).shape == (2, 16)

    def test_conv_updown(self):
        pc = nn.conv2d_init(KEY, 3, 8, 4)
        pt = nn.conv_transpose2d_init(KEY, 8, 3, 4)
        img = jax.random.normal(KEY, (2, 16, 16, 3))
        down = nn.conv2d_apply(pc, img)
        assert down.shape == (2, 8, 8, 8)
        up = nn.conv_transpose2d_apply(pt, down)
        assert up.shape == (2, 16, 16, 3)


class TestTensorParallel:
    """Megatron column/row-parallel paths (nn/tp.py, linear, mlp) must
    reproduce the dense math — forward AND gradients — with the model
    axis simulated by `jax.vmap(axis_name=...)` (the real shard_map
    execution is pinned by the TP equivalence matrix)."""

    AXIS = "model"
    TP = 2

    def _split(self, x, dim):
        return jnp.stack(jnp.split(x, self.TP, axis=dim))

    def _rep(self, x):
        return jnp.broadcast_to(x[None], (self.TP,) + x.shape)

    def test_linear_column_then_row_matches_dense(self):
        k1, k2, kx = jax.random.split(KEY, 3)
        w1 = jax.random.normal(k1, (8, 12))
        w2 = jax.random.normal(k2, (12, 6))
        x = jax.random.normal(kx, (3, 8))
        ref = jnp.tanh(x @ w1) @ w2

        def tp_fn(w1s, w2s):
            h = jnp.tanh(nn.linear_apply({"w": w1s}, x,
                                         tp_axis=self.AXIS,
                                         tp_mode="column"))
            return nn.linear_apply({"w": w2s}, h, tp_axis=self.AXIS,
                                   tp_mode="row")

        out = jax.vmap(tp_fn, axis_name=self.AXIS)(
            self._split(w1, -1), self._split(w2, 0))
        for r in range(self.TP):
            np.testing.assert_allclose(out[r], ref, atol=1e-5)

    def test_linear_gather_output_matches_dense(self):
        kw, kx = jax.random.split(KEY)
        w = jax.random.normal(kw, (8, 12))
        x = jax.random.normal(kx, (3, 8))
        ref = x @ w
        out = jax.vmap(
            lambda ws: nn.linear_apply({"w": ws}, x, tp_axis=self.AXIS,
                                       tp_mode="column",
                                       gather_output=True),
            axis_name=self.AXIS)(self._split(w, -1))
        for r in range(self.TP):
            np.testing.assert_allclose(out[r], ref, atol=1e-5)

    def test_linear_tp_requires_mode(self):
        p = nn.linear_init(KEY, 8, 12, use_bias=False)
        with pytest.raises(ValueError, match="tp_mode"):
            jax.vmap(lambda w: nn.linear_apply({"w": w}, jnp.ones((2, 8)),
                                               tp_axis=self.AXIS),
                     axis_name=self.AXIS)(self._rep(p["w"]))

    def _shard_mlp(self, p):
        sh = {"w_in": self._split(p["w_in"], -1),
              "w_out": self._split(p["w_out"], 0)}
        if "w_gate" in p:
            sh["w_gate"] = self._split(p["w_gate"], -1)
        if "b_in" in p:
            sh["b_in"] = self._split(p["b_in"], -1)
        if "b_out" in p:
            sh["b_out"] = self._rep(p["b_out"])
        return sh

    @pytest.mark.parametrize("gated,use_bias", [(True, False),
                                                (False, True)])
    def test_mlp_tp_matches_dense_forward_and_grad(self, gated, use_bias):
        p = nn.mlp_init(KEY, 16, 32, gated=gated, use_bias=use_bias)
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16))

        def loss_dense(p):
            return jnp.sum(nn.mlp_apply(p, x) ** 2)

        def loss_tp(ps):
            return jnp.sum(nn.mlp_apply(ps, x, tp_axis=self.AXIS) ** 2)

        np.testing.assert_allclose(
            jax.vmap(lambda ps: nn.mlp_apply(ps, x, tp_axis=self.AXIS),
                     axis_name=self.AXIS)(self._shard_mlp(p))[0],
            nn.mlp_apply(p, x), atol=1e-4)

        g_dense = self._shard_mlp(jax.grad(loss_dense)(p))
        g_tp = jax.vmap(jax.grad(loss_tp),
                        axis_name=self.AXIS)(self._shard_mlp(p))
        # replicated b_out grads are identical per rank (each rank sees
        # the full replicated cotangent), matching the dense grad
        for name in g_dense:
            ref = (g_dense[name] if name != "b_out"
                   else self._rep(jax.grad(loss_dense)(p)["b_out"]))
            np.testing.assert_allclose(np.asarray(g_tp[name]),
                                       np.asarray(ref), atol=1e-3,
                                       rtol=1e-4)

    def test_fused_gate_rejects_tp(self):
        p = nn.mlp_init(KEY, 16, 32, fuse_gate=True)
        with pytest.raises(ValueError, match="fuse_gate"):
            jax.vmap(lambda ps: nn.mlp_apply(ps, jnp.ones((2, 16)),
                                             tp_axis=self.AXIS),
                     axis_name=self.AXIS)(
                jax.tree.map(self._rep, p))

    def test_tp_helpers_identity_without_axis(self):
        x = jnp.arange(6.0).reshape(2, 3)
        np.testing.assert_array_equal(nn.copy_to_tp(x, None), x)
        np.testing.assert_array_equal(nn.reduce_from_tp(x, None), x)
        np.testing.assert_array_equal(nn.gather_from_tp(x, None), x)
        assert nn.tp_rank(None) == 0

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn

KEY = jax.random.PRNGKey(0)


def _setup(E=4, d=16, ff=32):
    p = nn.moe_init(KEY, d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
    return p, x


def test_output_shape_and_aux():
    p, x = _setup()
    y, aux = nn.moe_apply(p, x, n_experts=4, top_k=2, group_size=8)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
    # balanced-uniform router gives aux ~= n_experts * E * (1/E * 1/E) * E = 1
    assert 0.5 < float(aux) < 4.0


def test_dispatch_paths_agree_when_no_drops():
    p, x = _setup()
    kw = dict(n_experts=4, top_k=2, group_size=8, capacity_factor=8.0)
    y1, _ = nn.moe_apply(p, x, dispatch="einsum", **kw)
    y2, _ = nn.moe_apply(p, x, dispatch="sort", **kw)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_dropless_exactness():
    """Dropless result = dense mixture computed per token by hand."""
    p, x = _setup()
    y, _ = nn.moe_apply(p, x, n_experts=4, top_k=2, dropless=True)
    # manual: run every expert on every token, combine with top-2 gates
    x2d = x.reshape(-1, x.shape[-1])
    logits = x2d @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, idx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    from repro.nn.mlp import mlp_apply
    outs = jnp.stack([mlp_apply(jax.tree.map(lambda w: w[e], p["experts"]),
                                x2d) for e in range(4)])
    manual = jnp.zeros_like(x2d)
    for slot in range(2):
        manual += gate[:, slot, None] * jnp.take_along_axis(
            outs, idx[:, slot][None, :, None], axis=0)[0]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, x.shape[-1])),
                               np.asarray(manual), atol=1e-5)


def test_tiny_capacity_drops_tokens():
    p, x = _setup()
    y, _ = nn.moe_apply(p, x, n_experts=4, top_k=2, group_size=8,
                        capacity_factor=0.1)
    # with almost no capacity most tokens drop -> output mostly zero
    frac_zero = float((jnp.abs(y) < 1e-9).mean())
    assert frac_zero > 0.3


def test_capacity_loss_balanced_router():
    """Aux loss floor for a uniform router is top_k (chosen mass sums to
    k per token: E * sum_e (k/E * 1/E) * E/E = k)."""
    p, x = _setup()
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])   # uniform probs
    _, aux = nn.moe_apply(p, x, n_experts=4, top_k=2, group_size=8)
    assert float(aux) == pytest.approx(2.0, rel=1e-3)

"""Serving correctness: prefill + decode == full forward, per architecture."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch_config, list_archs
from repro.models import gan

KEY = jax.random.PRNGKey(0)


def _enc(cfg, b):
    if cfg.family == "encdec":
        return jax.random.normal(KEY, (b, cfg.enc_seq, cfg.d_model))
    if cfg.family == "vlm":
        return jax.random.normal(KEY, (b, cfg.n_image_tokens, cfg.d_model))
    return None


@pytest.mark.parametrize("name", list_archs())
def test_prefill_decode_matches_full(name):
    import dataclasses
    cfg = get_arch_config(name).reduced()
    params = gan.generator_init(KEY, cfg)
    b, s = 2, 17
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
    enc = _enc(cfg, b)
    # serving routes droplessly; compare against a full forward that also
    # never capacity-drops (train-mode dispatch with unbounded capacity)
    full_cfg = cfg
    if cfg.moe is not None:
        full_cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    full = gan.generator_lm_apply(params, full_cfg, toks, mode="train",
                                  enc_feats=enc, remat=False)
    pre = gan.generator_lm_apply(params, cfg, toks[:, :s], mode="prefill",
                                 enc_feats=enc, remat=False,
                                 prefill_cache_len=s + 1)
    dec = gan.generator_lm_apply(params, cfg, toks[:, s:], mode="decode",
                                 caches=pre["caches"],
                                 cache_index=jnp.int32(s), remat=False)
    np.testing.assert_allclose(
        np.asarray(dec["logits"][:, 0], np.float32),
        np.asarray(full["logits"][:, -1], np.float32), atol=2e-4)


@pytest.mark.parametrize("name", ["granite-3-2b", "mamba2-130m",
                                  "zamba2-2.7b", "gemma3-12b"])
def test_multi_step_greedy_decode(name):
    """Greedy multi-token decode == greedy decode over growing prefixes."""
    cfg = get_arch_config(name).reduced()
    params = gan.generator_init(KEY, cfg)
    b, s0, steps = 1, 8, 4
    toks = jax.random.randint(KEY, (b, s0), 0, cfg.vocab)
    max_len = s0 + steps

    pre = gan.generator_lm_apply(params, cfg, toks, mode="prefill",
                                 remat=False, prefill_cache_len=max_len)
    caches = pre["caches"]
    cur = jnp.argmax(pre["logits"][:, -1:], -1)
    produced = [cur]
    for t in range(steps - 1):
        out = gan.generator_lm_apply(params, cfg, cur, mode="decode",
                                     caches=caches,
                                     cache_index=jnp.int32(s0 + t),
                                     remat=False)
        caches = out["caches"]
        cur = jnp.argmax(out["logits"][:, -1:], -1)
        produced.append(cur)
    produced = jnp.concatenate(produced, axis=1)

    # reference: recompute full forward each step
    ref_toks = toks
    for t in range(steps):
        out = gan.generator_lm_apply(params, cfg, ref_toks, mode="train",
                                     remat=False)
        nxt = jnp.argmax(out["logits"][:, -1:], -1)
        ref_toks = jnp.concatenate([ref_toks, nxt], axis=1)
    np.testing.assert_array_equal(np.asarray(produced),
                                  np.asarray(ref_toks[:, s0:]))


def test_sliding_window_ring_buffer():
    """Decode with a window-sized ring cache == full-cache windowed decode."""
    import dataclasses
    cfg = get_arch_config("gemma3-12b").reduced()
    params = gan.generator_init(KEY, cfg)
    b, s = 1, 12
    toks = jax.random.randint(KEY, (b, s + 1), 0, cfg.vocab)
    full = gan.generator_lm_apply(params, cfg, toks, mode="train",
                                  remat=False)
    pre = gan.generator_lm_apply(params, cfg, toks[:, :s], mode="prefill",
                                 remat=False, prefill_cache_len=s + 1)
    dec = gan.generator_lm_apply(params, cfg, toks[:, s:], mode="decode",
                                 caches=pre["caches"],
                                 cache_index=jnp.int32(s), remat=False)
    np.testing.assert_allclose(
        np.asarray(dec["logits"][:, 0], np.float32),
        np.asarray(full["logits"][:, -1], np.float32), atol=2e-4)

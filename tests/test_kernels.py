"""Per-kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp oracles, in interpret mode (CPU executes the kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(0)


class TestWavg:
    @pytest.mark.parametrize("k,n", [(2, 64), (10, 2048), (16, 5000),
                                     (3, 1)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, k, n, dtype):
        from repro.kernels.wavg.ops import weighted_average
        from repro.kernels.wavg.ref import wavg_ref
        x = jax.random.normal(KEY, (k, n), dtype=dtype)
        w = jax.random.uniform(jax.random.PRNGKey(1), (k,))
        w = w / w.sum()
        out = weighted_average(x, w, interpret=True)
        ref = wavg_ref(x, w)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=1e-5 if dtype == jnp.float32 else 0.02)

    def test_nd_tensor(self):
        from repro.kernels.wavg.ops import weighted_average
        x = jax.random.normal(KEY, (4, 3, 5, 7))
        w = jnp.asarray([0.1, 0.2, 0.3, 0.4])
        out = weighted_average(x, w, interpret=True)
        ref = jnp.einsum("k,kabc->abc", w, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

    @pytest.mark.parametrize("n", [1, 2047, 2048, 2049, 4096, 4097])
    def test_padded_output_slicing_at_block_edges(self, n):
        """The wrapper pads N up to BLOCK_N and slices the kernel output
        back to n — exact at 1 element, exactly-BLOCK_N, and BLOCK_N+1
        (and the 2-block edges), with no padding garbage leaking in."""
        from repro.kernels.wavg.kernel import BLOCK_N
        from repro.kernels.wavg.ops import weighted_average
        from repro.kernels.wavg.ref import wavg_ref
        assert BLOCK_N == 2048, "parametrization assumes BLOCK_N=2048"
        k = 4
        x = jax.random.normal(KEY, (k, n))
        w = jax.random.uniform(jax.random.PRNGKey(1), (k,))
        w = w / w.sum()
        out = weighted_average(x, w, interpret=True)
        assert out.shape == (n,)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(wavg_ref(x, w)), atol=1e-5)

    def test_single_device_row(self):
        """K=1 (one mesh slice's contribution) must reduce to w*x."""
        from repro.kernels.wavg.ops import weighted_average
        x = jax.random.normal(KEY, (1, 37))
        out = weighted_average(x, jnp.ones(1), interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x[0]),
                                   atol=1e-6)

    def test_matches_protocol_averaging(self):
        """The kernel path must agree with core.averaging (impl='jnp')."""
        from repro.core.averaging import weighted_average as core_avg
        tree = {"a": jax.random.normal(KEY, (5, 33)),
                "b": {"c": jax.random.normal(KEY, (5, 4, 9))}}
        w = jnp.asarray([1.0, 2.0, 0.0, 4.0, 1.5])
        ref = core_avg(tree, w, impl="jnp")
        out = core_avg(tree, w, impl="pallas")
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)

    def test_psum_pallas_flat_path_matches_jnp(self):
        """weighted_average_psum impl='pallas' (flat all-gather + one
        kernel, the mesh-round hot path) == the per-leaf psum impl, on a
        1-slice shard_map so the fast lane covers it without a forced
        multi-device host."""
        from repro.core.averaging import weighted_average_psum
        from repro.core.shard_round import _shard_map
        from repro.launch.mesh import make_host_mesh
        from jax.sharding import PartitionSpec as P

        mesh = make_host_mesh(1, 1)
        tree = {"a": jax.random.normal(KEY, (6, 5)),
                "b": {"c": jax.random.normal(KEY, (3, 2, 4))}}
        w = jnp.float32(4.0)
        specs = jax.tree.map(lambda _: P(), tree)

        def run(impl):
            body = lambda t, lw: weighted_average_psum(
                t, lw, axis_names=("data",), impl=impl)
            return _shard_map(body, mesh=mesh, in_specs=(specs, P()),
                              out_specs=specs)(tree, w)

        ref, out = run("jnp"), run("pallas")
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


class TestTrimmedWavg:
    """The robust-aggregation kernel (kernels/robust_avg): coordinate
    trimmed mean with participation-mask-aware trimming, against the
    numpy ref twin."""

    @pytest.mark.parametrize("k,n", [(4, 64), (8, 2048), (10, 3000),
                                     (3, 1), (16, 2049)])
    @pytest.mark.parametrize("trim", [0, 1, 2])
    def test_matches_ref(self, k, n, trim):
        from repro.kernels.robust_avg.ops import trimmed_average
        from repro.kernels.robust_avg.ref import trimmed_mean_ref
        x = jax.random.normal(KEY, (k, n))
        w = jax.random.uniform(jax.random.PRNGKey(1), (k,))
        w = jnp.where(w < 0.2, 0.0, w)      # some dropped workers
        out = trimmed_average(x, w, trim=trim, interpret=True)
        ref = trimmed_mean_ref(np.asarray(x, np.float64),
                               np.asarray(w, np.float64), trim=trim)
        assert out.shape == (n,)
        np.testing.assert_allclose(np.asarray(out),
                                   ref.astype(np.float32), atol=2e-5)

    @pytest.mark.parametrize("n", [2047, 2048, 2049])
    def test_block_edges(self, n):
        """BLOCK_N padding must not leak pad columns into the trim
        statistics (pad entries are excluded like dropped workers)."""
        from repro.kernels.robust_avg.ops import trimmed_average
        from repro.kernels.robust_avg.ref import trimmed_mean_ref
        x = jax.random.normal(KEY, (6, n))
        w = jnp.ones(6)
        out = trimmed_average(x, w, trim=1, interpret=True)
        ref = trimmed_mean_ref(np.asarray(x, np.float64),
                               np.ones(6), trim=1)
        np.testing.assert_allclose(np.asarray(out),
                                   ref.astype(np.float32), atol=2e-5)

    def test_trim_actually_removes_extremes(self):
        """Plant one +1000 and one -1000 row: trim=1 must recover the
        honest coordinate means."""
        from repro.kernels.robust_avg.ops import trimmed_average
        honest = jax.random.normal(KEY, (6, 128))
        x = jnp.concatenate(
            [honest, jnp.full((1, 128), 1000.0),
             jnp.full((1, 128), -1000.0)])
        out = trimmed_average(x, jnp.ones(8), trim=1, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(honest.mean(0)), atol=1e-4)

    def test_psum_robust_path_matches_tree_level(self):
        """weighted_average_psum(robust=...) — the mesh robust hot path
        (flat all-gather + ONE kernel) — must agree with the stacked
        tree-level `weighted_average(robust=...)` on the same payload,
        for every robust method, on a 1-slice shard_map."""
        from repro.core.averaging import (weighted_average,
                                          weighted_average_psum)
        from repro.core.shard_round import _shard_map
        from repro.kernels.robust_avg import RobustConfig
        from repro.launch.mesh import make_host_mesh
        from jax.sharding import PartitionSpec as P

        mesh = make_host_mesh(1, 1)
        tree = {"a": jax.random.normal(KEY, (6, 5)),
                "b": {"c": jax.random.normal(KEY, (3, 2, 4))}}
        w = jnp.float32(4.0)
        w_full = jnp.full((1,), 4.0)
        specs = jax.tree.map(lambda _: P(), tree)

        # the tree-level API takes a STACKED tree (leading K axis); the
        # 1-slice psum path sees the same payload as a K=1 stack
        stacked = jax.tree.map(lambda x: x[None], tree)
        for method in ("trimmed_mean", "norm_clip", "krum"):
            cfg = RobustConfig(method=method, trim=0, krum_f=0)
            body = lambda t, lw: weighted_average_psum(
                t, lw, axis_names=("data",), robust=cfg)
            out = _shard_map(body, mesh=mesh, in_specs=(specs, P()),
                             out_specs=specs)(tree, w)
            ref = weighted_average(stacked, w_full, robust=cfg)
            for a, b in zip(jax.tree_util.tree_leaves(out),
                            jax.tree_util.tree_leaves(ref)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, err_msg=method)

    def test_robust_psum_hot_path_is_one_gather_one_kernel(self):
        """Acceptance criterion: every robust reducer keeps the
        Algorithm-2 hot path at ONE payload all-gather (+ the (K,)
        weight gather) and ONE Pallas kernel call per round — counted
        in the traced jaxpr of `weighted_average_psum`."""
        from repro.core.averaging import weighted_average_psum
        from repro.kernels.robust_avg import RobustConfig

        tree = {"a": jnp.zeros((33,)), "b": {"c": jnp.zeros((2, 17))}}
        w = jnp.float32(1.0)

        def counts(robust, impl="pallas"):
            fn = lambda t, lw: weighted_average_psum(
                t, lw, axis_names=("data",), impl=impl, robust=robust)
            jaxpr = str(jax.make_jaxpr(
                fn, axis_env=[("data", 4)])(tree, w))
            # count eqns, not substrings: every all_gather eqn also
            # prints an `all_gather_dimension=` param
            return (jaxpr.count("all_gather["),
                    jaxpr.count("pallas_call["))

        for method in ("trimmed_mean", "norm_clip", "krum"):
            gathers, kernels = counts(RobustConfig(method=method))
            assert kernels == 1, (method, kernels)
            assert gathers == 2, (method, gathers)   # payload + weights
        # the plain pallas path has the same collective budget
        gathers, kernels = counts(None)
        assert kernels == 1 and gathers == 2


class TestSSDScan:
    @pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (16, 16),
                                         (7, 8)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, s, chunk, dtype):
        from repro.kernels.ssd_scan.ops import ssd_scan
        from repro.nn.ssm import ssd_scan_ref
        ks = jax.random.split(KEY, 5)
        b, h, p, g, n = 2, 4, 16, 2, 8
        x = jax.random.normal(ks[0], (b, s, h, p), dtype=dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.4)
        B = jax.random.normal(ks[3], (b, s, g, n))
        C = jax.random.normal(ks[4], (b, s, g, n))
        y_k = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
        y_r = ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(y_k, np.float32), np.asarray(y_r, np.float32),
            atol=1e-4 if dtype == jnp.float32 else 0.05)

    def test_final_state_handoff(self):
        """Kernel prefill state must seed the decode recurrence exactly."""
        from repro.kernels.ssd_scan.ops import ssd_scan
        from repro.nn.ssm import ssd_scan_ref
        ks = jax.random.split(KEY, 5)
        b, s, h, p, n = 1, 24, 2, 8, 4
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.4)
        B = jax.random.normal(ks[3], (b, s, 1, n))
        C = jax.random.normal(ks[4], (b, s, 1, n))
        _, st_k = ssd_scan(x, dt, A, B, C, chunk=8, return_final_state=True,
                           interpret=True)
        _, st_r = ssd_scan_ref(x, dt, A, B, C, chunk=8,
                               return_final_state=True)
        np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r),
                                   atol=1e-4)

    def test_mixer_integration(self):
        """scan_impl hook: the mixer with the Pallas path == reference."""
        from repro import nn
        from repro.kernels.ssd_scan import ops as ssd_ops
        p = nn.ssd_mixer_init(KEY, 32, d_state=8, head_dim=16)
        x = jax.random.normal(KEY, (2, 24, 32))
        kw = dict(d_state=8, head_dim=16, chunk=8)
        y_ref = nn.ssd_mixer_apply(p, x, **kw)
        y_ker = nn.ssd_mixer_apply(
            p, x, scan_impl=lambda *a, **k: ssd_ops.ssd_scan(
                *a, **{**k, "interpret": True}), **kw)
        np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                                   atol=1e-4)


class TestFlashAttn:
    @pytest.mark.parametrize("s,window", [(32, None), (40, 9), (64, 16),
                                          (24, None)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_naive(self, s, window, dtype):
        from repro.kernels.flash_attn.ops import flash_attention
        from repro.kernels.flash_attn.ref import naive_ref
        ks = jax.random.split(KEY, 3)
        b, nh, nkv, hd = 2, 4, 2, 16
        q = jax.random.normal(ks[0], (b, s, nh, hd), dtype=dtype)
        k = jax.random.normal(ks[1], (b, s, nkv, hd), dtype=dtype)
        v = jax.random.normal(ks[2], (b, s, nkv, hd), dtype=dtype)
        out = flash_attention(q, k, v, n_kv_heads=nkv, window=window,
                              bq=16, bk=16, interpret=True)
        g = nh // nkv
        kr = jnp.repeat(k, g, axis=2)
        vr = jnp.repeat(v, g, axis=2)
        qf = jnp.moveaxis(q, 2, 1).reshape(b * nh, s, hd)
        kf = jnp.moveaxis(kr, 2, 1).reshape(b * nh, s, hd)
        vf = jnp.moveaxis(vr, 2, 1).reshape(b * nh, s, hd)
        ref = naive_ref(qf, kf, vf, scale=hd ** -0.5, causal=True,
                        window=window)
        ref = jnp.moveaxis(ref.reshape(b, nh, s, hd), 1, 2)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=2e-5 if dtype == jnp.float32 else 0.05)

    def test_agrees_with_model_attention(self):
        """Kernel output == the model's attention (flash_ref path)."""
        from repro.kernels.flash_attn.ops import flash_attention
        from repro.kernels.flash_attn.ref import flash_ref
        ks = jax.random.split(KEY, 3)
        b, s, h, hd = 1, 48, 2, 8
        q = jax.random.normal(ks[0], (b, s, h, hd))
        k = jax.random.normal(ks[1], (b, s, h, hd))
        v = jax.random.normal(ks[2], (b, s, h, hd))
        out = flash_attention(q, k, v, n_kv_heads=h, bq=16, bk=16,
                              interpret=True)
        qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, hd)
        kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, hd)
        vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, hd)
        ref = flash_ref(qf, kf, vf, scale=hd ** -0.5)
        ref = jnp.moveaxis(ref.reshape(b, h, s, hd), 1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

"""Tensor-parallel serving (train-to-serve): a tp=2 engine must load an
UNMODIFIED global-shaped training checkpoint and emit tokens identical
to tp=1 greedy decode.

Contract (ISSUE 10 acceptance):

  * checkpoints are GLOBAL-shaped at every training tp width (see
    test_tp_equivalence.py) — serving re-shards them on entry via
    `rules.tp_param_specs`, so ANY checkpoint serves at ANY serving tp;
  * sampling is keyed by (seed, rid, token_index) and computed
    replicated on every rank, so tp can only change matmul reduction
    order — greedy argmax over well-separated logits is bitwise stable
    on the reduced test config;
  * paged and dense backends both shard (the paged pool is replicated
    state; only params shard).

Runs in CI's mesh-tp lane (same subprocess pin style as
test_tp_equivalence.py; the serving mesh is (1, model=2), carved from
the forced host device pool).
"""
import pytest

from conftest import run_on_host_mesh

_TP_SERVE = """
    import tempfile
    import jax, numpy as np
    from repro.checkpoint import save_checkpoint
    from repro.configs import get_arch_config
    from repro.launch.serve import load_generator_params
    from repro.models import gan
    from repro.serving import ServingEngine, Request

    cfg = get_arch_config("qwen3-1.7b").reduced()
    params = gan.generator_init(jax.random.PRNGKey(0), cfg)

    # round-trip through a Trainer-layout checkpoint: global-shaped on
    # disk, loaded back exactly as launch/serve.py loads it
    d = tempfile.mkdtemp()
    save_checkpoint(d, 3, {"state": {"gen": params}})
    loaded, step = load_generator_params(d)
    assert step == 3
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    rng = np.random.default_rng(0)
    workload = [(rng.integers(1, cfg.vocab,
                              int(rng.integers(3, 14))).astype(np.int32),
                 int(rng.integers(3, 7)))
                for _ in range(4)]

    outs = {}
    for tp, block in ((1, 8), (2, 8), (2, None)):
        eng = ServingEngine(cfg, loaded, batch_size=2, max_len=32,
                            block_size=block, prefill_chunk=4, tp=tp)
        for i, (p, n) in enumerate(workload):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n))
        fin = eng.run()
        assert len(fin) == len(workload), \\
            [r.failed for r in eng.rejected]
        outs[(tp, block)] = {r.rid: list(r.out_tokens) for r in fin}
        print(f"tp={tp} block={block} OK")

    assert outs[(1, 8)] == outs[(2, 8)]      # tp=2 == tp=1, token-exact
    assert outs[(2, 8)] == outs[(2, None)]   # paged == dense under tp
    print("tp serving equivalence OK")
"""


@pytest.mark.slow
def test_tp2_serves_global_checkpoint_token_identical():
    """tp=2 paged + dense engines load a global-shaped checkpoint and
    match tp=1 greedy token-for-token, in one forced-2-device
    subprocess."""
    run_on_host_mesh(_TP_SERVE, n_devices=2)

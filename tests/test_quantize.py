"""Uplink quantization invariants (core/quantize.py).

Property-based tests run when `hypothesis` (a dev-only extra,
requirements-dev.txt) is importable — guarded like tests/test_property.py
so the tier-1 suite stays green without it. The same check functions are
exercised unconditionally by seeded twins, so the invariants are pinned
in every environment.
"""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


def make_tree(seed: int, n: int = 256):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.standard_normal((n // 16, 16)), jnp.float32),
        "b": jnp.asarray(rng.uniform(-3.0, 3.0, n), jnp.float32),
        "nested": [jnp.asarray(rng.standard_normal(7), jnp.float32)],
    }


def levels(bits: int) -> int:
    return 2 ** (bits - 1) - 1


# ---------------------------------------------------------------------------
# Shared checks (called by both the hypothesis and the seeded tests)
# ---------------------------------------------------------------------------

def check_identity_at_32_bits(tree, bits):
    out = quantize.roundtrip(KEY, tree, bits=bits)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def check_error_bound_per_leaf(key, tree, bits):
    """|roundtrip(x) - x| <= max|x| / (2^(bits-1) - 1) per leaf (the
    per-tensor scale; stochastic rounding moves at most one level)."""
    out = quantize.roundtrip(key, tree, bits=bits)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        scale = float(jnp.max(jnp.abs(b))) / levels(bits)
        err = float(jnp.max(jnp.abs(a - b)))
        assert err <= scale * (1 + 1e-5) + 1e-7, (bits, err, scale)


def check_error_shrinks_with_bits(key, tree, bits_lo, bits_hi):
    """Mean |error| strictly shrinks as bits grow (scale shrinks 4x per
    +2 bits, so the means are far separated over >=256 elements)."""
    def mean_err(bits):
        out = quantize.roundtrip(key, tree, bits=bits)
        errs = [jnp.abs(a - b).mean()
                for a, b in zip(jax.tree_util.tree_leaves(out),
                                jax.tree_util.tree_leaves(tree))]
        return float(sum(errs) / len(errs))

    assert mean_err(bits_hi) < mean_err(bits_lo), (bits_lo, bits_hi)


def check_structure_preserved(key, tree, bits):
    out = quantize.roundtrip(key, tree, bits=bits)
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(tree))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype and a.shape == b.shape


def check_tree_bits_exact(tree, bits):
    total = sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))
    assert quantize.tree_bits(tree, bits) == bits * total


# ---------------------------------------------------------------------------
# Hypothesis property tests (CI / dev environments)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=25, deadline=None)

    @settings(**SETTINGS)
    @given(bits=st.integers(32, 64), seed=st.integers(0, 2 ** 16))
    def test_prop_bits_ge_32_is_identity(bits, seed):
        check_identity_at_32_bits(make_tree(seed), bits)

    @settings(**SETTINGS)
    @given(bits=st.integers(2, 16), seed=st.integers(0, 2 ** 16))
    def test_prop_error_bounded_by_scale(bits, seed):
        check_error_bound_per_leaf(jax.random.PRNGKey(seed),
                                   make_tree(seed), bits)

    @settings(**SETTINGS)
    @given(bits_lo=st.integers(3, 10), step=st.integers(2, 6),
           seed=st.integers(0, 2 ** 16))
    def test_prop_error_monotone_in_bits(bits_lo, step, seed):
        check_error_shrinks_with_bits(jax.random.PRNGKey(seed),
                                      make_tree(seed, n=512),
                                      bits_lo, bits_lo + step)

    @settings(**SETTINGS)
    @given(bits=st.integers(2, 31), seed=st.integers(0, 2 ** 16))
    def test_prop_dtype_and_treedef_preserved(bits, seed):
        check_structure_preserved(jax.random.PRNGKey(seed),
                                  make_tree(seed), bits)

    @settings(**SETTINGS)
    @given(bits=st.integers(1, 64), seed=st.integers(0, 2 ** 16))
    def test_prop_tree_bits_counts_exactly(bits, seed):
        check_tree_bits_exact(make_tree(seed), bits)


# ---------------------------------------------------------------------------
# Seeded twins (always run)
# ---------------------------------------------------------------------------

class TestQuantizeSeeded:
    @pytest.mark.parametrize("bits", [32, 48])
    def test_bits_ge_32_is_identity(self, bits):
        check_identity_at_32_bits(make_tree(0), bits)

    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_error_bounded_by_scale(self, bits):
        for seed in range(5):
            check_error_bound_per_leaf(jax.random.PRNGKey(seed),
                                       make_tree(seed), bits)

    def test_error_monotone_in_bits(self):
        for seed in range(5):
            for lo, hi in ((4, 6), (6, 8), (8, 12)):
                check_error_shrinks_with_bits(jax.random.PRNGKey(seed),
                                              make_tree(seed, n=512),
                                              lo, hi)

    @pytest.mark.parametrize("bits", [5, 16])
    def test_dtype_and_treedef_preserved(self, bits):
        check_structure_preserved(KEY, make_tree(1), bits)

    @pytest.mark.parametrize("bits", [1, 8, 16, 32])
    def test_tree_bits_counts_exactly(self, bits):
        check_tree_bits_exact(make_tree(2), bits)

    def test_quantize_tree_int_levels_in_range(self):
        tree = make_tree(3)
        q, scales = quantize.quantize_tree(KEY, tree, bits=8)
        for leaf in jax.tree_util.tree_leaves(q):
            assert leaf.dtype == jnp.int32
            assert int(leaf.max()) <= levels(8)
            assert int(leaf.min()) >= -levels(8) - 1

    def test_roundtrip_stacked_matches_per_device_roundtrip(self):
        """The vmapped stacked uplink must equal per-device roundtrips
        with `device_uplink_key` — the contract that makes the vmap,
        scan, and shard_map layouts quantize identically."""
        k_dev, bits = 3, 8
        rng = np.random.default_rng(7)
        stacked = {"w": jnp.asarray(
            rng.standard_normal((k_dev, 5, 4)), jnp.float32)}
        out = quantize.roundtrip_stacked(KEY, stacked, bits)
        for i in range(k_dev):
            ref = quantize.roundtrip(
                quantize.device_uplink_key(KEY, i),
                {"w": stacked["w"][i]}, bits)
            np.testing.assert_array_equal(np.asarray(out["w"][i]),
                                          np.asarray(ref["w"]))


class TestRoundtripTp:
    """`roundtrip_tp`: a TP shard must quantize BITWISE like its slice
    of the full-tensor `roundtrip` — same global stream, same
    pmax-global scale — so TP width never changes the quantizer."""

    AXIS = "model"

    def _shard(self, tree, dims, tp):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        out = []
        for x, d in zip(leaves, dims):
            if d is None:
                out.append(jnp.broadcast_to(x[None], (tp,) + x.shape))
            else:
                out.append(jnp.stack(jnp.split(x, tp, axis=d % x.ndim)))
        return jax.tree_util.tree_unflatten(treedef, out)

    @pytest.mark.parametrize("bits", [8, 16])
    @pytest.mark.parametrize("tp", [2, 4])
    def test_matches_full_roundtrip_slice_bitwise(self, bits, tp):
        from repro.sharding import rules
        tree = {"w_in": jax.random.normal(KEY, (8, 16)),
                "w_out": jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (16, 12)),
                "ln": jax.random.normal(jax.random.fold_in(KEY, 2), (9,))}
        dims = rules.tp_tree_dims(tree, tp)
        assert dims == (None, -1, -2)   # ln replicated; w_in col; w_out row
        sharded = self._shard(tree, dims, tp)
        key = jax.random.fold_in(KEY, 3)
        full = self._shard(quantize.roundtrip(key, tree, bits), dims, tp)
        got = jax.vmap(
            lambda t: quantize.roundtrip_tp(key, t, bits,
                                            tp_axis=self.AXIS, tp=tp,
                                            shard_dims=dims),
            axis_name=self.AXIS)(sharded)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(full)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_tp1_and_high_bits_pass_through(self):
        tree = {"w_in": jax.random.normal(KEY, (4, 8))}
        out = quantize.roundtrip_tp(KEY, tree, 16, tp_axis=None, tp=1,
                                    shard_dims=None)
        ref = quantize.roundtrip(KEY, tree, 16)
        np.testing.assert_array_equal(np.asarray(out["w_in"]),
                                      np.asarray(ref["w_in"]))
        out32 = quantize.roundtrip_tp(KEY, tree, 32, tp_axis="model",
                                      tp=2, shard_dims=(None,))
        assert out32 is tree

    def test_replicated_leaves_stay_replicated(self):
        """A leaf the name rules replicate must come back IDENTICAL on
        every rank (same stream slice, same local scale)."""
        tree = {"ln": jax.random.normal(KEY, (11,))}
        sharded = self._shard(tree, (None,), 2)
        out = jax.vmap(
            lambda t: quantize.roundtrip_tp(KEY, t, 8, tp_axis=self.AXIS,
                                            tp=2, shard_dims=(None,)),
            axis_name=self.AXIS)(sharded)
        np.testing.assert_array_equal(np.asarray(out["ln"][0]),
                                      np.asarray(out["ln"][1]))

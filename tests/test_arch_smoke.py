"""Per-architecture smoke tests (assignment requirement f), plus
end-to-end smoke runs of the FedGAN-comparison entry points
(examples/fedgan_compare.py, benchmarks/fig5_fedgan.py) on BOTH
execution layouts — pinning their `--layout` plumbing so neither script
silently assumes stacked again.

Each assigned architecture instantiates its REDUCED variant (<=2 layers
of its group pattern, d_model<=256, <=4 experts) and runs ONE forward
and ONE protocol train round on CPU, asserting output shapes and no
NaNs. The FULL configs are exercised only by the dry-run.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch_config, list_archs
from repro.configs.base import ProtocolConfig
from repro.core import protocol
from repro.models import gan
from repro.models.specs import make_backbone_spec, make_stub_enc_feats

KEY = jax.random.PRNGKey(0)
SEQ = 16
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _spec_and_params(name):
    cfg = get_arch_config(name).reduced()
    params = gan.gan_init(KEY, cfg)
    enc_fn = make_stub_enc_feats(cfg)
    spec = make_backbone_spec(cfg, SEQ, enc_feats_fn=enc_fn, remat=False)
    return cfg, spec, params


@pytest.mark.parametrize("name", list_archs())
def test_forward_shapes_and_finiteness(name):
    cfg, spec, params = _spec_and_params(name)
    z = spec.sample_z(KEY, 2)
    fake = spec.gen_apply(params["gen"], z)
    assert fake.shape == (2, SEQ, cfg.d_model)
    assert jnp.isfinite(fake).all(), f"{name}: NaN in generator output"
    toks = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab)
    real_logits = spec.disc_real(params["disc"], toks)
    fake_logits = spec.disc_fake(params["disc"], fake)
    assert real_logits.shape == (2,) and fake_logits.shape == (2,)
    assert jnp.isfinite(real_logits).all() and jnp.isfinite(fake_logits).all()


@pytest.mark.parametrize("name", list_archs())
def test_one_train_round(name):
    cfg, spec, params = _spec_and_params(name)
    k_dev, n_k = 2, 4
    pcfg = ProtocolConfig(n_devices=k_dev, n_d=1, n_g=1, sample_size=2,
                          server_sample_size=2, lr_d=1e-3, lr_g=1e-3)
    state = protocol.make_train_state(
        KEY, lambda k: gan.gan_init(k, cfg), pcfg, k_dev)
    data = jax.random.randint(KEY, (k_dev, n_k, SEQ), 0, cfg.vocab)
    weights = jnp.full((k_dev,), float(pcfg.sample_size))
    new_state, metrics = protocol.gan_round(spec, pcfg, state, data,
                                            weights, KEY)
    for leaf in jax.tree_util.tree_leaves(new_state):
        assert jnp.isfinite(leaf).all(), f"{name}: non-finite after round"
    assert jnp.isfinite(metrics["disc_objective"])
    # the round must actually move both networks
    g0 = jax.tree_util.tree_leaves(state["gen"])
    g1 = jax.tree_util.tree_leaves(new_state["gen"])
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(g0, g1))
    d0 = jax.tree_util.tree_leaves(state["disc"])
    d1 = jax.tree_util.tree_leaves(new_state["disc"])
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(d0, d1))


# ---------------------------------------------------------------------------
# FedGAN-comparison entry points: --layout smoke (satellite of the
# layout x algorithm matrix; slow-marked, run in the CI mesh lane)
# ---------------------------------------------------------------------------

def _run_script(argv, *, n_devices=0, env_extra=None, timeout=540):
    """Run a repo script in a subprocess (optionally with a forced
    multi-device host platform — the main pytest process must keep the
    single-device view, see tests/conftest.py)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if n_devices:
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={n_devices}"
    env.update(env_extra or {})
    out = subprocess.run([sys.executable] + argv, capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=timeout)
    assert out.returncode == 0, \
        f"{argv} failed:\n{out.stdout[-2000:]}\n{out.stderr[-4000:]}"
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["stacked", "mesh"])
def test_fedgan_compare_example_runs_on_layout(layout):
    """examples/fedgan_compare.py --layout {stacked,mesh}: both
    algorithms complete a round and report FID/wallclock/uplink on the
    requested layout (mesh on a forced 4-device host)."""
    out = _run_script(
        ["examples/fedgan_compare.py", "--rounds", "1", "--layout",
         layout, "--devices", "4", "--data", "64"],
        n_devices=4 if layout == "mesh" else 0)
    assert "proposed-serial" in out and "fedgan" in out
    assert "FID=" in out and "[fused]" in out


@pytest.mark.slow
@pytest.mark.parametrize("layout", ["stacked", "mesh"])
def test_fig5_bench_runs_on_layout(tmp_path, layout):
    """benchmarks/fig5_fedgan.py --smoke --layout {stacked,mesh}: the
    Fig. 5 sweep writes a per-layout curves JSON with both algorithms'
    rows."""
    out = _run_script(
        ["benchmarks/fig5_fedgan.py", "--smoke", "--layout", layout,
         "--devices", "4", "--out-dir", str(tmp_path)],
        n_devices=4 if layout == "mesh" else 0,
        env_extra={"REPRO_BENCH_ROUNDS": "2",
                   "REPRO_BENCH_EVAL_EVERY": "2"})
    assert f"fig5_proposed-serial_{layout}" in out
    assert f"fig5_fedgan_{layout}" in out
    assert (tmp_path / f"fig5_fedgan_{layout}.json").exists()

"""Per-architecture smoke tests (assignment requirement f).

Each assigned architecture instantiates its REDUCED variant (<=2 layers
of its group pattern, d_model<=256, <=4 experts) and runs ONE forward
and ONE protocol train round on CPU, asserting output shapes and no
NaNs. The FULL configs are exercised only by the dry-run.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch_config, list_archs
from repro.configs.base import ProtocolConfig
from repro.core import protocol
from repro.models import gan
from repro.models.specs import make_backbone_spec, make_stub_enc_feats

KEY = jax.random.PRNGKey(0)
SEQ = 16


def _spec_and_params(name):
    cfg = get_arch_config(name).reduced()
    params = gan.gan_init(KEY, cfg)
    enc_fn = make_stub_enc_feats(cfg)
    spec = make_backbone_spec(cfg, SEQ, enc_feats_fn=enc_fn, remat=False)
    return cfg, spec, params


@pytest.mark.parametrize("name", list_archs())
def test_forward_shapes_and_finiteness(name):
    cfg, spec, params = _spec_and_params(name)
    z = spec.sample_z(KEY, 2)
    fake = spec.gen_apply(params["gen"], z)
    assert fake.shape == (2, SEQ, cfg.d_model)
    assert jnp.isfinite(fake).all(), f"{name}: NaN in generator output"
    toks = jax.random.randint(KEY, (2, SEQ), 0, cfg.vocab)
    real_logits = spec.disc_real(params["disc"], toks)
    fake_logits = spec.disc_fake(params["disc"], fake)
    assert real_logits.shape == (2,) and fake_logits.shape == (2,)
    assert jnp.isfinite(real_logits).all() and jnp.isfinite(fake_logits).all()


@pytest.mark.parametrize("name", list_archs())
def test_one_train_round(name):
    cfg, spec, params = _spec_and_params(name)
    k_dev, n_k = 2, 4
    pcfg = ProtocolConfig(n_devices=k_dev, n_d=1, n_g=1, sample_size=2,
                          server_sample_size=2, lr_d=1e-3, lr_g=1e-3)
    state = protocol.make_train_state(
        KEY, lambda k: gan.gan_init(k, cfg), pcfg, k_dev)
    data = jax.random.randint(KEY, (k_dev, n_k, SEQ), 0, cfg.vocab)
    weights = jnp.full((k_dev,), float(pcfg.sample_size))
    new_state, metrics = protocol.gan_round(spec, pcfg, state, data,
                                            weights, KEY)
    for leaf in jax.tree_util.tree_leaves(new_state):
        assert jnp.isfinite(leaf).all(), f"{name}: non-finite after round"
    assert jnp.isfinite(metrics["disc_objective"])
    # the round must actually move both networks
    g0 = jax.tree_util.tree_leaves(state["gen"])
    g1 = jax.tree_util.tree_leaves(new_state["gen"])
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(g0, g1))
    d0 = jax.tree_util.tree_leaves(state["disc"])
    d1 = jax.tree_util.tree_leaves(new_state["disc"])
    assert any(float(jnp.abs(a - b).max()) > 0 for a, b in zip(d0, d1))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import fedgan, quantize
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)
CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=16)
SPEC = make_dcgan_spec(CFG)


def test_fedgan_round_runs_and_moves_both_nets():
    pcfg = ProtocolConfig(n_devices=3, n_d=2, sample_size=4)
    state = fedgan.make_fedgan_state(KEY, lambda k: dcgan.gan_init(k, CFG),
                                     pcfg, 3)
    data = jax.random.normal(KEY, (3, 8, 16, 16, 1))
    w = jnp.full((3,), 4.0)
    new_state, metrics = fedgan.fedgan_round(SPEC, pcfg, state, data, w, KEY)
    for leaf in jax.tree_util.tree_leaves(new_state):
        assert jnp.isfinite(leaf).all()
    for net in ("gen", "disc"):
        a = jax.tree_util.tree_leaves(state[net])
        b = jax.tree_util.tree_leaves(new_state[net])
        assert any(float(jnp.abs(x - y).max()) > 0 for x, y in zip(a, b))
    assert metrics["participation"] == 1.0


def test_fedgan_uploads_twice_the_bytes():
    """The communication asymmetry Fig. 5 measures: FedGAN uploads
    theta AND phi; the proposed framework uploads phi only."""
    params = dcgan.gan_init(KEY, CFG)
    disc_bits = quantize.tree_bits(params["disc"], 16)
    both_bits = quantize.tree_bits(params, 16)
    assert both_bits > 1.5 * disc_bits


def test_quantize_roundtrip():
    tree = {"w": jax.random.normal(KEY, (64, 64))}
    out = quantize.roundtrip(KEY, tree, bits=16)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(tree["w"]), atol=1e-3)
    out8 = quantize.roundtrip(KEY, tree, bits=8)
    err8 = float(jnp.abs(out8["w"] - tree["w"]).max())
    scale = float(jnp.abs(tree["w"]).max())
    assert err8 <= scale / 127 + 1e-6


def test_quantize_unbiased():
    x = {"w": jnp.full((2000,), 0.31)}
    keys = jax.random.split(KEY, 30)
    means = [float(quantize.roundtrip(k, x, bits=4)["w"].mean())
             for k in keys]
    assert abs(np.mean(means) - 0.31) < 5e-3

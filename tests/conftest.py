import os
import subprocess
import sys
import textwrap

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device (per the dry-run contract). Tests
# that need a multi-device host mesh spawn a subprocess with XLA_FLAGS.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def run_on_host_mesh(code: str, n_devices: int = 8, timeout: int = 560):
    """Run `code` in a subprocess with a forced n-device host platform
    (the multi-device test harness — see the NOTE above for why the
    main pytest process must keep the single-device view)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    return out.stdout

"""Property tests: `averaging.weighted_average_psum(impl="pallas")` —
the mesh layout's Algorithm-2 hot path (flatten → one all-gather → the
Pallas `wavg` kernel) — against the pure per-leaf-psum reference
(impl="jnp") that the stacked layout's semantics define.

The collectives run under `jax.vmap(..., axis_name=...)`, which gives
`lax.psum`/`lax.all_gather` a real named axis of size K on a single
CPU device — so the whole property sweep runs in-process, no forced
multi-device subprocess needed (the real shard_map execution is pinned
by tests/test_multidevice.py and the mesh equivalence matrix).

Hypothesis runs when importable (requirements-dev.txt, guarded like
tests/test_quantize.py); every generated case is derived from a drawn
SEED, so a shrunk failure reproduces from the seed alone. The same
check functions run unconditionally on seeded twins, so the invariants
are pinned in every environment. Leaf-size strategies deliberately land
the flattened payload on BLOCK_N edges (BLOCK_N - 1, BLOCK_N,
BLOCK_N + 1, and the 2-block edges), forcing the kernel wrapper's
padded tail slices.
"""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.averaging import weighted_average_psum
from repro.kernels.wavg.kernel import BLOCK_N

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

AXIS = "k"


def run_impl(tree_stacked, weights, impl):
    """weighted_average_psum over a vmap-named device axis; the result
    is replicated, so slice 0 is THE average."""
    out = jax.vmap(
        lambda t, w: weighted_average_psum(t, w, axis_names=AXIS,
                                           impl=impl),
        axis_name=AXIS)(tree_stacked, weights)
    return out, jax.tree.map(lambda x: x[0], out)


def make_case(seed: int, *, k=None, sizes=None, dtypes=None,
              zero_weights=False):
    """Random stacked pytree + weights, fully determined by `seed`."""
    rng = np.random.default_rng(seed)
    k = k or int(rng.integers(1, 9))
    if sizes is None:
        sizes = [int(rng.integers(1, 300))
                 for _ in range(int(rng.integers(1, 4)))]
    if dtypes is None:
        dtypes = [jnp.float32 if rng.integers(2) else jnp.bfloat16
                  for _ in sizes]
    tree = {
        f"leaf{i}": jnp.asarray(
            rng.standard_normal((k, n)) * rng.uniform(0.1, 10.0),
            dt)
        for i, (n, dt) in enumerate(zip(sizes, dtypes))
    }
    if zero_weights:
        w = jnp.zeros(k, jnp.float32)
    else:
        w = jnp.asarray(rng.uniform(0.0, 5.0, k), jnp.float32)
        # some devices unscheduled (weight exactly 0), like Step 1 output
        w = jnp.where(jnp.asarray(rng.uniform(size=k) < 0.3), 0.0, w)
    return tree, w


def block_edge_sizes(rng, blocks: int):
    """Leaf sizes whose payload total lands next to a BLOCK_N edge,
    forcing the kernel wrapper's padded tail slice."""
    total = blocks * BLOCK_N + int(rng.integers(-2, 3))
    head = int(rng.integers(1, 64))
    return [head, max(1, total - head)]


# ---------------------------------------------------------------------------
# Shared checks (called by both the hypothesis and the seeded tests)
# ---------------------------------------------------------------------------

def check_pallas_matches_psum_reference(tree, w):
    """The Pallas hot path must agree with the per-leaf psum reference
    leaf-for-leaf, preserving structure, shape, and dtype."""
    _, pal = run_impl(tree, w, "pallas")
    _, ref = run_impl(tree, w, "jnp")
    assert (jax.tree_util.tree_structure(pal)
            == jax.tree_util.tree_structure(ref))
    for a, b in zip(jax.tree_util.tree_leaves(pal),
                    jax.tree_util.tree_leaves(ref)):
        assert a.dtype == b.dtype and a.shape == b.shape
        atol = 1e-5 if a.dtype == jnp.float32 else 0.02
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)


def check_result_replicated_across_devices(tree, w):
    """Every slice must hold the SAME average (the broadcast invariant
    Step 5 relies on)."""
    stacked, _ = run_impl(tree, w, "pallas")
    for leaf in jax.tree_util.tree_leaves(stacked):
        first = np.asarray(leaf[0:1], np.float32)
        np.testing.assert_array_equal(
            np.broadcast_to(first, leaf.shape),
            np.asarray(leaf, np.float32))


def check_weight_scale_invariance(tree, w, scale: float):
    """Weights are normalized, so w and scale*w give the same average
    (Algorithm 2 depends on the m_k ratios only)."""
    _, a = run_impl(tree, w, "pallas")
    _, b = run_impl(tree, w * scale, "pallas")
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        atol = 1e-5 if x.dtype == jnp.float32 else 0.02
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


# ---------------------------------------------------------------------------
# Hypothesis property tests (CI / dev environments)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=20, deadline=None)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16))
    def test_prop_pallas_matches_psum_random_trees(seed):
        tree, w = make_case(seed)
        check_pallas_matches_psum_reference(tree, w)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16), blocks=st.integers(1, 2))
    def test_prop_pallas_matches_psum_at_block_edges(seed, blocks):
        rng = np.random.default_rng(seed)
        tree, w = make_case(seed, sizes=block_edge_sizes(rng, blocks))
        check_pallas_matches_psum_reference(tree, w)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16))
    def test_prop_result_replicated(seed):
        tree, w = make_case(seed)
        check_result_replicated_across_devices(tree, w)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16),
           scale=st.floats(0.25, 64.0))
    def test_prop_weight_scale_invariance(seed, scale):
        tree, w = make_case(seed)
        check_weight_scale_invariance(tree, w, scale)


# ---------------------------------------------------------------------------
# Seeded twins (always run)
# ---------------------------------------------------------------------------

class TestPallasAveragingSeeded:
    @pytest.mark.parametrize("seed", range(5))
    def test_matches_psum_random_trees(self, seed):
        tree, w = make_case(seed)
        check_pallas_matches_psum_reference(tree, w)

    @pytest.mark.parametrize("blocks", [1, 2])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_psum_at_block_edges(self, seed, blocks):
        rng = np.random.default_rng(seed)
        tree, w = make_case(seed, sizes=block_edge_sizes(rng, blocks))
        check_pallas_matches_psum_reference(tree, w)

    def test_single_device_axis(self):
        tree, w = make_case(3, k=1, zero_weights=False)
        check_pallas_matches_psum_reference(tree, jnp.ones(1))

    def test_all_zero_weights_agree(self):
        """Nobody scheduled: both impls guard the normalizer the same
        way, so they must still agree (the engine's straggler-only
        rounds hit this)."""
        tree, w = make_case(4, k=4, zero_weights=True)
        check_pallas_matches_psum_reference(tree, w)

    def test_replicated_and_scale_invariant(self):
        tree, w = make_case(5)
        check_result_replicated_across_devices(tree, w)
        check_weight_scale_invariance(tree, w, 8.0)

    def test_bf16_leaves_roundtrip_dtype(self):
        tree, w = make_case(6, sizes=[33, 2048],
                            dtypes=[jnp.bfloat16, jnp.bfloat16])
        _, pal = run_impl(tree, w, "pallas")
        for leaf in jax.tree_util.tree_leaves(pal):
            assert leaf.dtype == jnp.bfloat16

    def test_empty_tree_short_circuits(self):
        out = weighted_average_psum({}, jnp.ones(()), axis_names=AXIS,
                                    impl="pallas")
        assert out == {}

    def test_unknown_impl_raises(self):
        with pytest.raises(ValueError, match="impl"):
            run_impl(*make_case(7), "warp")


class TestAxisSubsetAveraging:
    """`weighted_average_psum` over a SUBSET of the live axes — the 2-D
    (device x model) mesh's Algorithm 2: psum/all_gather on the device
    axis ONLY while a `model` axis is live, so each TP rank averages
    just its parameter shard. Nested `jax.vmap` axis names stand in for
    the 2-D mesh (the real shard_map execution is pinned by
    tests/test_tp_equivalence.py)."""

    MODEL = "model"

    def run_subset(self, tree_km, weights_k, impl):
        """tree_km leaves: (K, TP, n) — device axis K, model axis TP.
        Reduce over the device axis only; weights replicate over model.
        Returns the (K, TP, n) output (replicated over K per model
        rank)."""
        def slice_fn(t, w):
            return weighted_average_psum(t, w, axis_names=AXIS, impl=impl)

        # outer vmap = device axis K (named AXIS), inner = model axis TP:
        # after the outer slice a leaf is (TP, n), so the inner maps dim 0
        inner = jax.vmap(slice_fn, in_axes=(0, None),
                         axis_name=self.MODEL)
        return jax.vmap(inner, axis_name=AXIS)(tree_km, weights_k)

    def make_2d_case(self, seed, *, k=4, tp=2, sizes=None):
        rng = np.random.default_rng(seed)
        if sizes is None:
            sizes = [int(rng.integers(1, 200))
                     for _ in range(int(rng.integers(1, 4)))]
        tree = {f"leaf{i}": jnp.asarray(
                    rng.standard_normal((k, tp, n)) * rng.uniform(0.1, 4.0),
                    jnp.float32)
                for i, n in enumerate(sizes)}
        w = jnp.asarray(rng.uniform(0.0, 5.0, k), jnp.float32)
        w = jnp.where(jnp.asarray(rng.uniform(size=k) < 0.3), 0.0, w)
        return tree, w

    def reference(self, tree_km, weights_k):
        """Per-model-rank weighted mean over the device axis in numpy."""
        w = np.asarray(weights_k, np.float64)
        wn = w / max(w.sum(), 1e-12)

        def avg(x):
            x = np.asarray(x, np.float64)
            out = np.einsum("k,ktn->tn", wn, x)
            return np.broadcast_to(out[None], x.shape)

        return {name: avg(leaf) for name, leaf in tree_km.items()}

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_device_axis_subset_reduction(self, impl, seed):
        tree, w = self.make_2d_case(seed)
        out = self.run_subset(tree, w, impl)
        ref = self.reference(tree, w)
        for name in tree:
            np.testing.assert_allclose(np.asarray(out[name], np.float32),
                                       ref[name].astype(np.float32),
                                       atol=1e-5)

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_model_ranks_stay_independent(self, impl):
        """Different shards per model rank must NOT mix: the reduction
        touches the device axis only (a ("k", "model") reduction would
        collapse the model dim — the bug this pins against)."""
        tree, w = self.make_2d_case(3, k=3, tp=2, sizes=[17])
        out = self.run_subset(tree, w, impl)
        leaf = np.asarray(out["leaf0"], np.float32)
        # model rank 0 and 1 averaged DIFFERENT shards
        assert np.abs(leaf[:, 0] - leaf[:, 1]).max() > 1e-6
        ref = self.reference(tree, w)
        np.testing.assert_allclose(leaf, ref["leaf0"].astype(np.float32),
                                   atol=1e-5)

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    @pytest.mark.parametrize("blocks", [1, 2])
    def test_block_edge_payloads_under_live_model_axis(self, impl,
                                                      blocks):
        """BLOCK_N-edge payloads through the kernel wrapper's padded
        tail slice, with the model axis live."""
        rng = np.random.default_rng(blocks)
        tree, w = self.make_2d_case(5, sizes=block_edge_sizes(rng, blocks))
        out = self.run_subset(tree, w, impl)
        ref = self.reference(tree, w)
        for name in tree:
            np.testing.assert_allclose(np.asarray(out[name], np.float32),
                                       ref[name].astype(np.float32),
                                       atol=1e-5)

    def test_pallas_matches_jnp_on_subset(self):
        tree, w = self.make_2d_case(8)
        a = self.run_subset(tree, w, "pallas")
        b = self.run_subset(tree, w, "jnp")
        for x, y in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b)):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32),
                                       atol=1e-5)

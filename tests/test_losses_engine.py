"""Paper equations (1)-(2) and the host Trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.core.channel import ChannelConfig
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)


class TestLosses:
    def test_stable_at_extreme_logits(self):
        big = jnp.asarray([1e4, -1e4])
        assert jnp.isfinite(losses.disc_objective(big, big))
        assert jnp.isfinite(losses.gen_objective_minimax(big)).all()
        assert jnp.isfinite(losses.gen_objective_nonsaturating(big)).all()

    def test_disc_objective_maximized_by_correct_split(self):
        good = losses.disc_objective(jnp.asarray([5.0]), jnp.asarray([-5.0]))
        bad = losses.disc_objective(jnp.asarray([-5.0]), jnp.asarray([5.0]))
        confused = losses.disc_objective(jnp.asarray([0.0]),
                                         jnp.asarray([0.0]))
        assert good > confused > bad

    def test_nash_value(self):
        """At D = 1/2 (logit 0) the objective is log(1/2)+log(1/2)."""
        v = losses.disc_objective(jnp.zeros(4), jnp.zeros(4))
        assert float(v) == pytest.approx(2 * np.log(0.5), rel=1e-5)

    def test_gen_gradient_signs(self):
        """Both generator variants push fake logits UP."""
        g1 = jax.grad(lambda l: losses.gen_objective_minimax(l))(
            jnp.asarray([0.0]))
        g2 = jax.grad(lambda l: losses.gen_objective_nonsaturating(l))(
            jnp.asarray([0.0]))
        # descending these objectives increases the logit
        assert g1[0] < 0 and g2[0] < 0

    def test_minimax_saturates_nonsaturating_does_not(self):
        l = jnp.asarray([-20.0])   # D confidently rejects fakes
        g_mm = jax.grad(lambda x: losses.gen_objective_minimax(x))(l)
        g_ns = jax.grad(lambda x: losses.gen_objective_nonsaturating(x))(l)
        assert abs(float(g_mm[0])) < 1e-6      # saturated
        assert abs(float(g_ns[0])) > 0.1       # alive


class TestTrainer:
    def _mk(self, algorithm, **kw):
        cfg = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=16)
        spec = make_dcgan_spec(cfg)
        pcfg = ProtocolConfig(n_devices=3, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4, **kw)
        data = jnp.asarray(np.random.default_rng(0).standard_normal(
            (3, 8, 16, 16, 1)), jnp.float32)
        return Trainer(spec, pcfg, lambda k: dcgan.gan_init(k, cfg), data,
                       KEY, algorithm=algorithm,
                       channel_cfg=ChannelConfig(n_devices=3))

    @pytest.mark.parametrize("algorithm", ["proposed", "fedgan",
                                           "centralized"])
    def test_runs_and_clock_monotone(self, algorithm):
        tr = self._mk(algorithm)
        hist = tr.run(3)
        assert len(hist) == 3
        clocks = [h.cumulative_s for h in hist]
        assert all(b > a for a, b in zip(clocks, clocks[1:]))
        for leaf in jax.tree_util.tree_leaves(tr.state):
            assert jnp.isfinite(leaf).all()

    def test_partial_scheduling_participation(self):
        # ceil(0.3 * 3) = 1 of 3 devices scheduled per round
        tr = self._mk("proposed", scheduler="best_channel",
                      scheduling_ratio=0.3)
        hist = tr.run(2)
        assert hist[0].metrics["participation"] == pytest.approx(1 / 3)

    def test_checkpoint_roundtrip_through_trainer(self, tmp_path):
        from repro.checkpoint import save_checkpoint, load_checkpoint
        tr = self._mk("proposed")
        tr.run(1)
        save_checkpoint(str(tmp_path), 1, tr.state)
        loaded, _, _ = load_checkpoint(str(tmp_path))
        for a, b in zip(jax.tree_util.tree_leaves(tr.state),
                        jax.tree_util.tree_leaves(loaded)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

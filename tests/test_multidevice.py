"""Multi-device host-mesh tests, run in subprocesses so the main pytest
process keeps the default single-device view (per the dry-run contract,
XLA_FLAGS must not be set globally)."""
import pytest

from conftest import run_on_host_mesh as run_sub


@pytest.mark.slow
def test_shard_map_round_matches_vmap_round():
    """The explicit-psum (shard_map) protocol round must agree with the
    stacked/vmap (pjit) round on a real 4-device mesh."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ProtocolConfig
        from repro.configs.dcgan import DCGANConfig
        from repro.core import protocol
        from repro.core.shard_round import shard_map_round
        from repro.models import dcgan
        from repro.models.specs import make_dcgan_spec
        from repro.launch.mesh import make_host_mesh

        cfg = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=16)
        spec = make_dcgan_spec(cfg)
        pcfg = ProtocolConfig(n_devices=4, n_d=2, n_g=1, sample_size=4,
                              server_sample_size=4)
        key = jax.random.PRNGKey(0)
        state = protocol.make_train_state(
            key, lambda k: dcgan.gan_init(k, cfg), pcfg, 4)
        data = jax.random.normal(key, (4, 8, 16, 16, 1))
        w = jnp.asarray([4.0, 4.0, 0.0, 4.0])

        ref_state, ref_metrics = jax.jit(
            lambda s, d, ww, kk: protocol.gan_round(spec, pcfg, s, d, ww, kk)
        )(state, data, w, key)

        mesh = make_host_mesh(4, 1)
        run = shard_map_round(spec, pcfg, mesh, device_axes=("data",))
        sm_state, sm_metrics = run(state, data, w, key)

        for a, b in zip(jax.tree_util.tree_leaves(ref_state),
                        jax.tree_util.tree_leaves(sm_state)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-5)
        assert abs(float(ref_metrics["disc_objective"])
                   - float(sm_metrics["disc_objective"])) < 1e-4
        print("shard_map == vmap round OK")
    """)


@pytest.mark.slow
def test_mini_dryrun_train_and_decode_lower_on_mesh():
    """End-to-end mini dry-run: a reduced arch lowers + compiles on a
    (2, 4) host mesh through the production step builders."""
    run_sub("""
        import dataclasses, math
        import jax, jax.numpy as jnp
        from repro.configs import get_arch_config
        from repro.configs.base import MeshConfig, ShapeConfig
        from repro.launch import steps as steps_mod
        from repro.launch.analysis import analyze_compiled

        cfg = dataclasses.replace(get_arch_config('qwen3-1.7b').reduced(),
                                  vocab=512)
        from repro.launch.mesh import make_mesh, use_mesh
        mesh = make_mesh((2, 4), ('data', 'model'))
        mesh_cfg = MeshConfig()
        train_shape = ShapeConfig('mini_train', 32, 8, 'train')
        step, args = steps_mod.build_train_step(cfg, train_shape, mesh,
                                                mesh_cfg)
        with use_mesh(mesh):
            compiled = step.lower(*args).compile()
            r = analyze_compiled(compiled, 8)
        assert r['roofline']['flops'] > 0
        assert r['collectives']['total_bytes'] > 0, 'averaging must show up'
        print('train lowers OK', r['roofline']['dominant'])

        dec_shape = ShapeConfig('mini_decode', 64, 8, 'decode')
        step, args = steps_mod.build_decode_step(cfg, dec_shape, mesh,
                                                 mesh_cfg)
        with use_mesh(mesh):
            compiled = step.lower(*args).compile()
        print('decode lowers OK')

        pre_shape = ShapeConfig('mini_prefill', 64, 8, 'prefill')
        step, args = steps_mod.build_prefill_step(cfg, pre_shape, mesh,
                                                  mesh_cfg)
        with use_mesh(mesh):
            compiled = step.lower(*args).compile()
        print('prefill lowers OK')
    """)


@pytest.mark.slow
def test_mesh_layout_train_step_executes():
    """launch/steps.build_train_step(layout='mesh'): the fused shard_map
    rounds-scan executes on a real 8-device mesh for BOTH mesh
    algorithms, including a shorter remainder chunk through a second
    compile (any round count works). Three backbone-scale shard_map
    compiles in one subprocess — give it headroom over the default
    timeout."""
    run_sub(timeout=1100, code="""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch_config
        from repro.configs.base import MeshConfig, ShapeConfig
        from repro.core import protocol
        from repro.core.fedgan import make_fedgan_state
        from repro.launch import steps as steps_mod
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.models import gan as gan_model

        cfg = dataclasses.replace(get_arch_config('qwen3-1.7b').reduced(),
                                  vocab=256)
        mesh = make_mesh((8, 1), ('data', 'model'))
        shape = ShapeConfig('mesh_train', 16, 16, 'train')
        over = {'n_d': 1, 'n_g': 1}
        step2, args = steps_mod.build_train_step(
            cfg, shape, mesh, MeshConfig(), fuse_rounds=2, layout='mesh',
            pcfg_overrides=over)
        step1, _ = steps_mod.build_train_step(
            cfg, shape, mesh, MeshConfig(), fuse_rounds=1, layout='mesh',
            pcfg_overrides=over)
        state_abs, carry_abs, tokens_abs, key_abs, _ = args
        from repro.configs.base import ProtocolConfig
        pcfg = ProtocolConfig(n_devices=8, sample_size=2,
                              server_sample_size=8)
        state = protocol.make_train_state(
            jax.random.PRNGKey(0), lambda k: gan_model.gan_init(k, cfg),
            pcfg, 8)
        state = jax.tree.map(lambda x, a: jnp.asarray(x, a.dtype), state,
                             state_abs)
        carry = {'rr_cursor': jnp.int32(0),
                 'ewma_rate': jnp.ones(8, jnp.float32)}
        assert jax.eval_shape(lambda: carry) == carry_abs
        tokens = jnp.zeros(tokens_abs.shape, tokens_abs.dtype)
        key = jax.random.PRNGKey(0)
        with use_mesh(mesh):
            state, carry, out = step2(state, carry, tokens, key,
                                      jnp.int32(0))
            state, carry, out2 = step1(state, carry, tokens, key,
                                       jnp.int32(2))   # remainder chunk
        assert out['wallclock_s'].shape == (2,)
        assert out2['mask'].shape == (1, 8)
        for leaf in jax.tree_util.tree_leaves(state):
            assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
        print('mesh layout train step OK')

        # FedGAN through the SAME builder: two-net fused shard_map scan
        fstep, fargs = steps_mod.build_train_step(
            cfg, shape, mesh, MeshConfig(), fuse_rounds=2, layout='mesh',
            algorithm='fedgan', pcfg_overrides=over)
        fstate_abs = fargs[0]
        fstate = make_fedgan_state(
            jax.random.PRNGKey(0), lambda k: gan_model.gan_init(k, cfg),
            pcfg, 8)
        fstate = jax.tree.map(lambda x, a: jnp.asarray(x, a.dtype),
                              fstate, fstate_abs)
        # gen_opt is per-device on FedGAN (every device trains both nets)
        gen_opt_leaves = jax.tree_util.tree_leaves(fstate['gen_opt'])
        assert all(l.shape[0] == 8 for l in gen_opt_leaves)
        carry = {'rr_cursor': jnp.int32(0),
                 'ewma_rate': jnp.ones(8, jnp.float32)}
        with use_mesh(mesh):
            fstate, carry, fout = fstep(fstate, carry, tokens, key,
                                        jnp.int32(0))
        assert fout['wallclock_s'].shape == (2,)
        assert set(fout['metrics']) == {'participation'}
        for leaf in jax.tree_util.tree_leaves(fstate):
            assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())
        print('mesh layout fedgan train step OK')

        # stacked builder stays proposed-only (FedGAN stacked runs via
        # the Trainer, not the pod-scale builder)
        try:
            steps_mod.build_train_step(cfg, shape, mesh, MeshConfig(),
                                       layout='stacked',
                                       algorithm='fedgan')
        except ValueError as e:
            assert 'proposed' in str(e)
        else:
            raise AssertionError('stacked fedgan builder must raise')
        print('stacked builder algorithm guard OK')
    """)


@pytest.mark.slow
def test_mesh_layout_tp2_backbone_matches_tp1():
    """launch/steps.build_train_step(layout='mesh', tp=2) on a 16-device
    (8 data x 2 model) host mesh: the backbone's feed-forward blocks run
    Megatron column/row-parallel inside each worker slice and the fused
    scan reproduces the tp=1 run to bf16 round-off from the same initial
    state. Two backbone-scale shard_map compiles in one subprocess."""
    run_sub(n_devices=16, timeout=1100, code="""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_arch_config
        from repro.configs.base import MeshConfig, ProtocolConfig, ShapeConfig
        from repro.core import protocol
        from repro.launch import steps as steps_mod
        from repro.launch.mesh import make_mesh, use_mesh
        from repro.models import gan as gan_model
        from repro.sharding import rules

        cfg = dataclasses.replace(get_arch_config('qwen3-1.7b').reduced(),
                                  vocab=256)
        shape = ShapeConfig('mesh_tp', 16, 16, 'train')
        over = {'n_d': 1, 'n_g': 1}
        mesh2 = make_mesh((8, 2), ('data', 'model'))
        step2, args = steps_mod.build_train_step(
            cfg, shape, mesh2, MeshConfig(), fuse_rounds=2, layout='mesh',
            tp=2, pcfg_overrides=over)
        mesh1 = make_mesh((8, 1), ('data', 'model'))
        step1, _ = steps_mod.build_train_step(
            cfg, shape, mesh1, MeshConfig(), fuse_rounds=2, layout='mesh',
            tp=1, pcfg_overrides=over)

        state_abs, carry_abs, tokens_abs, key_abs, _ = args
        pcfg = ProtocolConfig(n_devices=8, sample_size=2,
                              server_sample_size=8)
        state = protocol.make_train_state(
            jax.random.PRNGKey(0), lambda k: gan_model.gan_init(k, cfg),
            pcfg, 8)
        state = jax.tree.map(lambda x, a: jnp.asarray(x, a.dtype), state,
                             state_abs)

        # the name rules actually shard the ff weights at this config
        dims = rules.tp_tree_dims(state['disc'], 2)
        assert any(d is not None for d in dims), 'nothing TP-sharded'
        assert rules.tp_local_size(state['disc'], 2) < sum(
            x.size for x in jax.tree_util.tree_leaves(state['disc']))

        def make_carry():   # fresh buffers: the steps donate their carry
            return {'rr_cursor': jnp.int32(0),
                    'ewma_rate': jnp.ones(8, jnp.float32)}
        tokens = jnp.zeros(tokens_abs.shape, tokens_abs.dtype)
        key = jax.random.PRNGKey(0)
        with use_mesh(mesh2):
            s2, c2, out2 = step2(jax.tree.map(jnp.copy, state),
                                 make_carry(), tokens, key, jnp.int32(0))
        with use_mesh(mesh1):
            s1, c1, out1 = step1(jax.tree.map(jnp.copy, state),
                                 make_carry(), tokens, key, jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(out1['mask']),
                                      np.asarray(out2['mask']))
        np.testing.assert_allclose(np.asarray(out1['wallclock_s']),
                                   np.asarray(out2['wallclock_s']),
                                   rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(s1),
                        jax.tree_util.tree_leaves(s2)):
            a32 = np.asarray(a, np.float32)
            b32 = np.asarray(b, np.float32)
            assert np.isfinite(b32).all()
            # bf16 state: TP changes only matmul reduction order
            np.testing.assert_allclose(a32, b32, atol=0.03,
                                       rtol=0.02)
        print('mesh tp=2 backbone matches tp=1 OK')
    """)


@pytest.mark.slow
def test_protocol_round_executes_on_mesh():
    """Actually EXECUTE (not just compile) one protocol round with the
    stacked axis sharded over a 4-device data axis."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ProtocolConfig
        from repro.configs.dcgan import DCGANConfig
        from repro.core import protocol
        from repro.models import dcgan
        from repro.models.specs import make_dcgan_spec
        from repro.launch.mesh import make_host_mesh, use_mesh

        cfg = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=16)
        spec = make_dcgan_spec(cfg)
        pcfg = ProtocolConfig(n_devices=4, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4)
        key = jax.random.PRNGKey(0)
        mesh = make_host_mesh(4, 1)
        state = protocol.make_train_state(
            key, lambda k: dcgan.gan_init(k, cfg), pcfg, 4)
        data = jax.device_put(
            jax.random.normal(key, (4, 8, 16, 16, 1)),
            NamedSharding(mesh, P('data')))
        w = jnp.full((4,), 4.0)
        with use_mesh(mesh):
            new_state, metrics = jax.jit(
                lambda s, d, ww, kk: protocol.gan_round(spec, pcfg, s, d,
                                                        ww, kk)
            )(state, data, w, key)
        assert all(bool(jnp.isfinite(x).all())
                   for x in jax.tree_util.tree_leaves(new_state))
        print('executed round on mesh OK')
    """)

"""Property-based tests (hypothesis) on the system's invariants.

hypothesis is a dev-only extra (requirements-dev.txt); without it this
module skips at collection instead of erroring the whole suite. The
seeded, dependency-free twins of the core invariants live in
tests/test_driver_equivalence.py / tests/test_channel_scheduling.py.
"""
import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.averaging import weighted_average, broadcast_like
from repro.core.quantize import roundtrip
from repro.nn.attention import build_mask
from repro.nn.ssm import ssd_scan_ref
from repro.data.partition import partition_iid

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(
    k=st.integers(2, 6),
    n=st.integers(1, 8),
    seed=st.integers(0, 2 ** 16),
)
def test_weighted_average_convexity(k, n, seed):
    """Algorithm 2 output lies in the convex hull of the inputs and is
    scale-invariant in the weights."""
    rng = np.random.default_rng(seed)
    stacked = {"p": jnp.asarray(rng.standard_normal((k, n)), jnp.float32)}
    w = jnp.asarray(rng.uniform(0.1, 5.0, k), jnp.float32)
    avg = weighted_average(stacked, w)["p"]
    lo = stacked["p"].min(0) - 1e-5
    hi = stacked["p"].max(0) + 1e-5
    assert bool(((avg >= lo) & (avg <= hi)).all())
    avg2 = weighted_average(stacked, 3.7 * w)["p"]
    np.testing.assert_allclose(np.asarray(avg), np.asarray(avg2), atol=1e-5)


@settings(**SETTINGS)
@given(k=st.integers(1, 5), seed=st.integers(0, 2 ** 16))
def test_average_of_identical_replicas_is_identity(k, seed):
    rng = np.random.default_rng(seed)
    p = {"w": jnp.asarray(rng.standard_normal((3, 2)), jnp.float32)}
    stacked = broadcast_like(p, k)
    w = jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)
    avg = weighted_average(stacked, w)
    np.testing.assert_allclose(np.asarray(avg["w"]), np.asarray(p["w"]),
                               atol=1e-6)


@settings(**SETTINGS)
@given(bits=st.integers(6, 16), seed=st.integers(0, 2 ** 16))
def test_quantize_error_bound(bits, seed):
    rng = np.random.default_rng(seed)
    x = {"w": jnp.asarray(rng.standard_normal(128), jnp.float32)}
    out = roundtrip(jax.random.PRNGKey(seed), x, bits=bits)
    levels = 2 ** (bits - 1) - 1
    bound = float(jnp.abs(x["w"]).max()) / levels + 1e-7
    assert float(jnp.abs(out["w"] - x["w"]).max()) <= bound


@settings(**SETTINGS)
@given(
    s=st.integers(2, 24),
    window=st.one_of(st.none(), st.integers(1, 30)),
    causal=st.booleans(),
)
def test_mask_row_has_allowed_entry(s, window, causal):
    """Every query with at least itself in range attends somewhere
    (causal self-attention always allows the diagonal)."""
    pos = jnp.arange(s)[None]
    m = build_mask(pos, pos, causal=causal, window=window)
    if causal:
        diag = np.diagonal(np.asarray(m[0]))
        np.testing.assert_array_equal(diag, 0.0)
    else:
        assert (np.asarray(m[0]) == 0).any(axis=1).all()


@settings(**SETTINGS)
@given(
    s=st.integers(4, 32),
    chunk=st.sampled_from([2, 4, 8, 16]),
    seed=st.integers(0, 2 ** 16),
)
def test_ssd_chunk_size_invariance(s, chunk, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, h, p, n = 1, 2, 4, 3
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    y1 = ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    y2 = ssd_scan_ref(x, dt, A, B, C, chunk=max(s, 1))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)


@settings(**SETTINGS)
@given(
    n=st.integers(10, 60),
    k=st.integers(1, 10),
    seed=st.integers(0, 2 ** 16),
)
def test_partition_rows_are_a_subset_without_duplicates(n, k, seed):
    data = np.arange(n)[:, None].astype(np.float32)
    shards = partition_iid(data, k, seed=seed)
    flat = shards.reshape(-1)
    assert len(set(flat.tolist())) == flat.size        # no duplicates
    assert set(flat.tolist()) <= set(range(n))         # subset of source
    assert shards.shape[0] == k


@settings(**SETTINGS)
@given(seed=st.integers(0, 2 ** 16), k=st.integers(2, 5))
def test_round_weight_zero_is_noop_weight(seed, k):
    """Adding a zero-weight replica never changes Algorithm 2's output."""
    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.standard_normal((k, 4)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)
    avg1 = weighted_average({"p": base}, w)["p"]
    extra = jnp.concatenate([base, 100.0 * jnp.ones((1, 4))])
    w2 = jnp.concatenate([w, jnp.zeros(1)])
    avg2 = weighted_average({"p": extra}, w2)["p"]
    np.testing.assert_allclose(np.asarray(avg1), np.asarray(avg2), atol=1e-5)

"""Property tests for the ring-collective Algorithm 2
(`kernels/ring_wavg`, `averaging.weighted_average_psum(impl="ring")`).

Same in-process harness as tests/test_averaging_property.py: the
collectives (`lax.ppermute`, `lax.all_gather`, `lax.psum`) run under
`jax.vmap(..., axis_name=...)`, which gives them a real named axis of
size K on one CPU device — the real shard_map execution is pinned by
the mesh equivalence matrix in tests/test_driver_equivalence.py.

Invariants pinned here:
  * ring == per-leaf psum reference == flat pallas path (round-off)
  * ring == the order-independent float64 numpy ref (ref.py), seeded
    twins — including the QUANTIZED wire (same device_uplink_key
    streams as the flat path's roundtrip)
  * the result is replicated on every slice
  * BLOCK/chunk edges: payload sizes 1, BLOCK_N +- 1, chunk-count
    boundaries (n_blocks = 1, chunks, chunks + 1), K not a power of two
  * zero total weight returns the fallback tree (no-survivor rounds)

Hypothesis runs when importable (requirements-dev.txt); every generated
case derives from a drawn SEED, so shrunk failures reproduce from the
seed alone, and the same check functions run on seeded twins in every
environment.
"""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize
from repro.core.averaging import weighted_average_psum
from repro.kernels.ring_wavg.kernel import BLOCK_N, ring_accum_pallas
from repro.kernels.ring_wavg.ops import (DEFAULT_CHUNKS, _chunk_bounds,
                                         ring_average_psum,
                                         ring_wire_bytes_per_rank)
from repro.kernels.ring_wavg.ref import ring_average_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

AXIS = "k"


def run_ring(tree_stacked, weights, **kw):
    out = jax.vmap(
        lambda t, w: ring_average_psum(t, w, axis_names=AXIS, **kw),
        axis_name=AXIS)(tree_stacked, weights)
    return out, jax.tree.map(lambda x: x[0], out)


def run_flat(tree_stacked, weights, impl):
    out = jax.vmap(
        lambda t, w: weighted_average_psum(t, w, axis_names=AXIS,
                                           impl=impl),
        axis_name=AXIS)(tree_stacked, weights)
    return jax.tree.map(lambda x: x[0], out)


def make_case(seed: int, *, k=None, sizes=None, dtypes=None,
              zero_weights=False):
    """Random stacked pytree + weights, fully determined by `seed`
    (the tests/test_averaging_property.py recipe)."""
    rng = np.random.default_rng(seed)
    k = k or int(rng.integers(1, 9))
    if sizes is None:
        sizes = [int(rng.integers(1, 300))
                 for _ in range(int(rng.integers(1, 4)))]
    if dtypes is None:
        dtypes = [jnp.float32 if rng.integers(2) else jnp.bfloat16
                  for _ in sizes]
    tree = {
        f"leaf{i}": jnp.asarray(
            rng.standard_normal((k, n)) * rng.uniform(0.1, 10.0),
            dt)
        for i, (n, dt) in enumerate(zip(sizes, dtypes))
    }
    if zero_weights:
        w = jnp.zeros(k, jnp.float32)
    else:
        w = jnp.asarray(rng.uniform(0.0, 5.0, k), jnp.float32)
        w = jnp.where(jnp.asarray(rng.uniform(size=k) < 0.3), 0.0, w)
    return tree, w


# ---------------------------------------------------------------------------
# Shared checks
# ---------------------------------------------------------------------------

def check_ring_matches_references(tree, w):
    """ring == per-leaf psum == flat pallas == float64 numpy ref, with
    structure/shape/dtype preserved."""
    _, ring = run_ring(tree, w)
    psum_ref = run_flat(tree, w, "jnp")
    ref64 = ring_average_ref(tree, w)
    assert (jax.tree_util.tree_structure(ring)
            == jax.tree_util.tree_structure(psum_ref))
    for a, b, c in zip(jax.tree_util.tree_leaves(ring),
                       jax.tree_util.tree_leaves(psum_ref),
                       jax.tree_util.tree_leaves(ref64)):
        assert a.dtype == b.dtype and a.shape == b.shape
        atol = 2e-5 if a.dtype == jnp.float32 else 0.02
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=atol)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=atol)


def check_quantized_ring_matches_ref(tree, w, seed, bits=16):
    """The encoded wire must realize the SAME quantized values as the
    flat path's per-device roundtrip streams (ref.py reuses
    quantize_tree with device_uplink_key): the only deviation allowed
    is f32-vs-f64 accumulation order."""
    k = jax.tree_util.tree_leaves(tree)[0].shape[0]
    round_key = jax.random.PRNGKey(seed)
    keys = jnp.stack([quantize.device_uplink_key(round_key, i)
                      for i in range(k)])
    out = jax.vmap(
        lambda t, wi, kk: ring_average_psum(t, wi, axis_names=AXIS,
                                            quantize_key=kk, bits=bits),
        axis_name=AXIS)(tree, w, keys)
    ring = jax.tree.map(lambda x: x[0], out)
    ref64 = ring_average_ref(tree, w, round_key=round_key, bits=bits)
    for a, c in zip(jax.tree_util.tree_leaves(ring),
                    jax.tree_util.tree_leaves(ref64)):
        atol = 2e-5 if a.dtype == jnp.float32 else 0.02
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=atol)


def check_replicated(tree, w):
    stacked, _ = run_ring(tree, w)
    for leaf in jax.tree_util.tree_leaves(stacked):
        first = np.asarray(leaf[0:1], np.float32)
        np.testing.assert_allclose(
            np.broadcast_to(first, leaf.shape),
            np.asarray(leaf, np.float32), atol=1e-6)


# ---------------------------------------------------------------------------
# Seeded twins (always run)
# ---------------------------------------------------------------------------

class TestRingSeeded:
    @pytest.mark.parametrize("seed", range(6))
    def test_matches_references(self, seed):
        tree, w = make_case(seed)
        check_ring_matches_references(tree, w)

    @pytest.mark.parametrize("seed", range(4))
    def test_quantized_matches_ref(self, seed):
        tree, w = make_case(seed + 100,
                            dtypes=None if seed % 2 else [jnp.float32])
        check_quantized_ring_matches_ref(tree, w, seed)

    @pytest.mark.parametrize("k", [1, 2, 3, 5, 7])
    def test_k_not_power_of_two(self, k):
        tree, w = make_case(11, k=k, sizes=[513, 40],
                            dtypes=[jnp.float32, jnp.float32])
        check_ring_matches_references(tree, w)
        check_quantized_ring_matches_ref(tree, w, 17)
        check_replicated(tree, w)

    @pytest.mark.parametrize("n", [1, BLOCK_N - 1, BLOCK_N, BLOCK_N + 1])
    def test_block_edges(self, n):
        tree, w = make_case(13, k=4, sizes=[n], dtypes=[jnp.float32])
        check_ring_matches_references(tree, w)

    @pytest.mark.parametrize("blocks",
                             [1, DEFAULT_CHUNKS, DEFAULT_CHUNKS + 1,
                              2 * DEFAULT_CHUNKS + 3])
    def test_chunk_count_edges(self, blocks):
        """n_blocks below / at / past the chunk count exercises the
        single-chunk path and the ragged last chunk."""
        tree, w = make_case(29, k=3, sizes=[blocks * BLOCK_N - 7],
                            dtypes=[jnp.float32])
        check_ring_matches_references(tree, w)
        check_quantized_ring_matches_ref(tree, w, 31)

    def test_zero_weights_returns_fallback(self):
        tree, w = make_case(41, k=4, zero_weights=True)
        fb = jax.tree.map(lambda x: jnp.ones_like(x[0]), tree)
        out = jax.vmap(
            lambda t, wi: ring_average_psum(t, wi, axis_names=AXIS,
                                            fallback=fb),
            axis_name=AXIS)(tree, w)
        for a, f in zip(jax.tree_util.tree_leaves(out),
                        jax.tree_util.tree_leaves(fb)):
            np.testing.assert_array_equal(np.asarray(a[0], np.float32),
                                          np.asarray(f, np.float32))

    def test_multi_axis_rejected(self):
        tree, w = make_case(43, k=2)
        with pytest.raises(NotImplementedError):
            jax.vmap(lambda t, wi: ring_average_psum(
                t, wi, axis_names=(AXIS, "m")), axis_name=AXIS)(tree, w)

    def test_ring_does_not_compose_with_robust(self):
        from repro.kernels.robust_avg import RobustConfig
        tree, w = make_case(47, k=2)
        with pytest.raises(ValueError):
            jax.vmap(lambda t, wi: weighted_average_psum(
                t, wi, axis_names=AXIS, impl="ring",
                robust=RobustConfig(method="trimmed_mean")),
                axis_name=AXIS)(tree, w)


# ---------------------------------------------------------------------------
# Kernel + helpers (no collectives)
# ---------------------------------------------------------------------------

class TestRingAccumKernel:
    @pytest.mark.parametrize("dtype,seed", [(jnp.int16, 0),
                                            (jnp.int32, 1),
                                            (jnp.float32, 2)])
    def test_accumulate_matches_numpy(self, dtype, seed):
        rng = np.random.default_rng(seed)
        nb = 3
        acc = rng.standard_normal((nb, BLOCK_N)).astype(np.float32)
        coef = rng.standard_normal(nb).astype(np.float32)
        if dtype == jnp.float32:
            q = rng.standard_normal((nb, BLOCK_N)).astype(np.float32)
        else:
            q = rng.integers(-1000, 1000, (nb, BLOCK_N)).astype(
                np.dtype(dtype))
        out = ring_accum_pallas(jnp.asarray(acc),
                                jnp.asarray(q, dtype),
                                jnp.asarray(coef), interpret=True)
        expect = acc + coef[:, None] * q.astype(np.float32)
        np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-6,
                                   atol=1e-5)

    def test_chunk_bounds_cover_exactly(self):
        for nb in (1, 2, 4, 5, 9, 64):
            for nc in (1, 2, 4, 7):
                bounds = _chunk_bounds(nb, nc)
                assert bounds[0][0] == 0 and bounds[-1][1] == nb
                for (a0, a1), (b0, b1) in zip(bounds, bounds[1:]):
                    assert a1 == b0 and a1 > a0
                assert len(bounds) == min(nc, nb)

    def test_wire_bytes_formula(self):
        tree = {"a": jnp.zeros((BLOCK_N + 1,)), "b": jnp.zeros((5,))}
        # 2 blocks for a, 1 for b; int16 wire + f32 scale per block
        assert ring_wire_bytes_per_rank(tree, 16, 8) == \
            7 * 3 * (BLOCK_N * 2 + 4)
        assert ring_wire_bytes_per_rank(tree, 32, 8) == \
            7 * 3 * (BLOCK_N * 4 + 4)


# ---------------------------------------------------------------------------
# Hypothesis sweep (guarded)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_ring_matches_references(seed):
        tree, w = make_case(seed)
        check_ring_matches_references(tree, w)

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_quantized_ring(seed):
        tree, w = make_case(seed)
        check_quantized_ring_matches_ref(tree, w, seed % 1000)

"""The loop-aware HLO cost parser (the dry-run profiler)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_costs import HloModule, hlo_costs


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    x = jnp.zeros((32, 64))
    w = jnp.zeros((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    costs = hlo_costs(compiled.as_text())
    expected = 7 * 2 * 32 * 64 * 64
    assert costs["flops"] == pytest.approx(expected, rel=0.01)


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jnp.zeros((16, 16))
    w = jnp.zeros((16, 16))
    compiled = jax.jit(f).lower(x, w).compile()
    costs = hlo_costs(compiled.as_text())
    expected = 5 * 3 * 2 * 16 * 16 * 16
    assert costs["flops"] == pytest.approx(expected, rel=0.01)


def test_straightline_dot():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((8, 32)), jnp.zeros((32, 4))).compile()
    costs = hlo_costs(compiled.as_text())
    assert costs["flops"] == pytest.approx(2 * 8 * 32 * 4, rel=0.01)
    assert costs["collective_bytes"] == 0


def test_hbm_counts_inputs_and_outputs():
    compiled = jax.jit(lambda a: a * 2.0 + 1.0).lower(
        jnp.zeros((1024,))).compile()
    costs = hlo_costs(compiled.as_text())
    # at least read + write of the 4KB buffer; fusion-level accounting
    assert 8e3 <= costs["hbm_bytes"] <= 1e5


def test_parser_handles_tuple_computations():
    """Computation headers with tuple-typed params must be recognized."""
    def f(x):
        def body(carry, _):
            a, b = carry
            return (b, a @ a), None
        (a, b), _ = jax.lax.scan(body, (x, x), None, length=4)
        return (a + b).sum()

    compiled = jax.jit(f).lower(jnp.zeros((8, 8))).compile()
    mod = HloModule(compiled.as_text())
    assert mod.entry is not None
    costs = mod.totals()
    assert costs["flops"] == pytest.approx(4 * 2 * 8 * 8 * 8, rel=0.05)

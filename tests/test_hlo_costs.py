"""The loop-aware HLO cost parser (the dry-run profiler) — and the
collective-byte contract it pins for the ring collective: at 16-bit
quantization the ring's per-rank wire traffic must be well under half
the flat all-gather path's (the payload travels encoded)."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_costs import HloModule, hlo_costs


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y.sum()

    x = jnp.zeros((32, 64))
    w = jnp.zeros((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    costs = hlo_costs(compiled.as_text())
    expected = 7 * 2 * 32 * 64 * 64
    assert costs["flops"] == pytest.approx(expected, rel=0.01)


def test_nested_scans_multiply():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y.sum()

    x = jnp.zeros((16, 16))
    w = jnp.zeros((16, 16))
    compiled = jax.jit(f).lower(x, w).compile()
    costs = hlo_costs(compiled.as_text())
    expected = 5 * 3 * 2 * 16 * 16 * 16
    assert costs["flops"] == pytest.approx(expected, rel=0.01)


def test_straightline_dot():
    compiled = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((8, 32)), jnp.zeros((32, 4))).compile()
    costs = hlo_costs(compiled.as_text())
    assert costs["flops"] == pytest.approx(2 * 8 * 32 * 4, rel=0.01)
    assert costs["collective_bytes"] == 0


def test_hbm_counts_inputs_and_outputs():
    compiled = jax.jit(lambda a: a * 2.0 + 1.0).lower(
        jnp.zeros((1024,))).compile()
    costs = hlo_costs(compiled.as_text())
    # at least read + write of the 4KB buffer; fusion-level accounting
    assert 8e3 <= costs["hbm_bytes"] <= 1e5


def test_parser_handles_tuple_computations():
    """Computation headers with tuple-typed params must be recognized."""
    def f(x):
        def body(carry, _):
            a, b = carry
            return (b, a @ a), None
        (a, b), _ = jax.lax.scan(body, (x, x), None, length=4)
        return (a + b).sum()

    compiled = jax.jit(f).lower(jnp.zeros((8, 8))).compile()
    mod = HloModule(compiled.as_text())
    assert mod.entry is not None
    costs = mod.totals()
    assert costs["flops"] == pytest.approx(4 * 2 * 8 * 8 * 8, rel=0.05)


@pytest.mark.slow
def test_ring_collective_bytes_beat_flat_on_mesh():
    """PR 9 acceptance: lower the fused mesh round scan for the flat
    pallas path (bits=16 but the payload is dequantized BEFORE the
    all-gather, so f32 travels) and the ring path (payload stays int16
    on the wire), and compare what the optimized HLO actually moves.

    Pins three things on a forced 8-device host mesh:
      * ring wire bytes == `ring_wire_bytes_per_rank` EXACTLY (the
        analytic formula driver_bench reports is what XLA emits)
      * ring / flat collective bytes <= 0.55 at 16-bit (the headline
        ~0.44: (K-1)*(N_pad*2 + 4/block) vs K*N*4)
      * the ring program contains NO payload all-gather (only the tiny
        weight gather survives)
    """
    from conftest import run_on_host_mesh
    out = run_on_host_mesh("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs.base import ProtocolConfig
        from repro.configs.dcgan import DCGANConfig
        from repro.core import Trainer
        from repro.core.channel import ChannelConfig
        from repro.kernels.ring_wavg.ops import ring_wire_bytes_per_rank
        from repro.launch.hlo_costs import hlo_costs
        from repro.models import dcgan
        from repro.models.specs import make_dcgan_spec

        KEY = jax.random.PRNGKey(0)
        # disc ~661k params: the payload must dwarf BLOCK_N padding for
        # the wire-byte comparison to be about encoding, not padding
        CFG = DCGANConfig(nz=16, ngf=16, ndf=64, nc=1, image_size=32)
        SPEC = make_dcgan_spec(CFG)
        K = 8
        DATA = jax.random.normal(jax.random.PRNGKey(9), (K, 4, 32, 32, 1))

        def lowered_costs(avg_impl):
            pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1,
                                  sample_size=2, server_sample_size=2,
                                  lr_d=1e-3, lr_g=1e-3, quantize_bits=16)
            chan = ChannelConfig(n_devices=K, seed=3, fading=False)
            tr = Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG),
                         DATA, KEY, channel_cfg=chan, driver="fused",
                         layout="mesh", avg_impl=avg_impl)
            fn = tr._chunk_fn(1)        # ONE round per dispatch
            text = jax.jit(fn).lower(tr.state, tr._sched_carry, tr.data,
                                     tr.key, jnp.int32(0)) \
                .compile().as_text()
            return hlo_costs(text), tr

        flat, tr = lowered_costs("pallas")
        ring, _ = lowered_costs("ring")
        print("RESULT " + json.dumps({
            "flat": flat["bytes_by_kind"],
            "ring": ring["bytes_by_kind"],
            "analytic": ring_wire_bytes_per_rank(tr.state["disc"], 16, K),
        }))
    """)
    res = json.loads(next(l for l in out.splitlines()
                          if l.startswith("RESULT ")).split(" ", 1)[1])
    flat_ag = res["flat"]["all-gather"]
    ring_cp = res["ring"]["collective-permute"]
    # the analytic formula is exact against the lowered HLO
    assert ring_cp == res["analytic"]
    # headline contract: encoded ring wire <= 0.55x the flat f32 gather
    assert ring_cp / flat_ag <= 0.55, (ring_cp, flat_ag)
    # the payload all-gather is GONE; anything left is the (K,) weight
    # vector and similar scalars
    assert res["ring"].get("all-gather", 0) <= 1024

"""FID parity: the jittable jnp implementation vs the numpy float64
oracle, and the fused driver's IN-SCAN FID vs the host loop.

Contract (metrics/fid.py design note): the jnp twin agrees with numpy
to ~1e-5 relative on random PSD covariances and on real extractor
features; with a jittable fid_fn the fused driver folds evaluation into
the scan via lax.cond — ONE compiled chunk function per run, no
eval-boundary recompiles — and its per-seed FID series matches the host
loop.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.core.channel import ChannelConfig
from repro.metrics import fid as fid_mod
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)
CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
SPEC = make_dcgan_spec(CFG)
K = 4
DATA = jax.random.normal(jax.random.PRNGKey(9), (K, 8, 8, 8, 1))


def random_psd(rng, d):
    a = rng.standard_normal((d, d))
    return a @ a.T / d + 0.1 * np.eye(d)


class TestJnpVsNumpy:
    @pytest.mark.parametrize("d", [4, 16, 64])
    def test_frechet_distance_on_random_psd(self, d):
        rng = np.random.default_rng(d)
        c1, c2 = random_psd(rng, d), random_psd(rng, d)
        mu1, mu2 = rng.standard_normal(d), rng.standard_normal(d)
        ref = fid_mod.frechet_distance(mu1, c1, mu2, c2)
        got = float(fid_mod.frechet_distance_jnp(mu1, c1, mu2, c2))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)

    def test_frechet_distance_identical_dists_is_zero(self):
        rng = np.random.default_rng(0)
        c = random_psd(rng, 8)
        mu = rng.standard_normal(8)
        assert float(fid_mod.frechet_distance_jnp(mu, c, mu, c)) == (
            pytest.approx(0.0, abs=1e-4))

    def test_feature_stats_matches_numpy(self):
        rng = np.random.default_rng(1)
        feats = rng.standard_normal((200, 32)).astype(np.float32)
        mu_np, cov_np = fid_mod.feature_stats(feats)
        mu_jx, cov_jx = fid_mod.feature_stats_jnp(jnp.asarray(feats))
        np.testing.assert_allclose(np.asarray(mu_jx), mu_np, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cov_jx), cov_np, atol=1e-5)

    def test_fid_on_real_extractor_features(self):
        feat = fid_mod.make_feature_extractor(1)
        x1 = jax.random.normal(jax.random.PRNGKey(1), (256, 8, 8, 1))
        x2 = jax.random.normal(jax.random.PRNGKey(2), (256, 8, 8, 1)) * 1.3
        f1, f2 = feat(x1), feat(x2)
        ref = fid_mod.fid_score(f1, f2)
        got = float(fid_mod.fid_score_jnp(f1, f2))
        np.testing.assert_allclose(got, ref, rtol=1e-3)
        assert got > 0.0

    def test_fid_score_jnp_is_jittable(self):
        f1 = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
        f2 = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
        eager = float(fid_mod.fid_score_jnp(f1, f2))
        jitted = float(jax.jit(fid_mod.fid_score_jnp)(f1, f2))
        np.testing.assert_allclose(jitted, eager, rtol=1e-5)


def make_fid_fn():
    feat = fid_mod.make_feature_extractor(1)
    real = feat(DATA.reshape(-1, 8, 8, 1))
    rmu, rcov = fid_mod.feature_stats_jnp(real)

    def fid_fn(gen_params, key):
        z = jax.random.normal(key, (64, CFG.nz))
        fake = dcgan.generator_apply(gen_params, CFG, z)
        mu, cov = fid_mod.feature_stats_jnp(feat(fake))
        return fid_mod.frechet_distance_jnp(rmu, rcov, mu, cov)

    return fid_fn


def make_trainer(driver, algorithm="proposed"):
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3)
    chan = ChannelConfig(n_devices=K, seed=3)
    return Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), DATA, KEY,
                   channel_cfg=chan, driver=driver, algorithm=algorithm)


class TestInScanFid:
    @pytest.mark.parametrize("algorithm", ["proposed", "fedgan"])
    def test_in_scan_fid_matches_host_loop(self, algorithm):
        fid_fn = make_fid_fn()
        th = make_trainer("host", algorithm)
        tf = make_trainer("fused", algorithm)
        h = th.run(6, eval_every=2, fid_fn=fid_fn)
        f = tf.run(6, eval_every=2, fid_fn=fid_fn)
        # one compiled chunk for the whole run — eval rounds force no
        # boundaries (and hence no per-boundary recompiles)
        assert len(tf._chunk_fns) == 1
        for rh, rf in zip(h, f):
            assert (rh.fid is None) == (rf.fid is None)
            if rh.fid is not None:
                np.testing.assert_allclose(rf.fid, rh.fid, rtol=1e-3)
        # eval rounds are exactly every eval_every
        assert [r.fid is not None for r in f] == [False, True] * 3

    def test_non_jittable_fid_falls_back_to_boundaries(self):
        """A numpy fid_fn cannot trace; the fused driver must still
        produce the right eval schedule via boundary chunking."""
        jit_fid = make_fid_fn()

        def numpy_fid(gen_params, key):
            return float(np.asarray(jit_fid(gen_params, key)))

        tf = make_trainer("fused")
        f = tf.run(4, eval_every=2, fid_fn=numpy_fid)
        assert [r.fid is not None for r in f] == [False, True, False, True]
        # no in-scan eval chunk was compiled (cache keys carry
        # eval_every=0), i.e. the host fallback really ran
        assert tf._chunk_fns and all(k[1] == 0 for k in tf._chunk_fns)

    def test_in_scan_fid_chunked_runs_match_one_shot(self):
        """run(2)+run(4) with in-scan FID equals run(6): absolute round
        indices key the eval schedule and the FID noise stream."""
        fid_fn = make_fid_fn()
        ta, tb = make_trainer("fused"), make_trainer("fused")
        ta.run(2, eval_every=2, fid_fn=fid_fn)
        ta.run(4, eval_every=2, fid_fn=fid_fn)
        tb.run(6, eval_every=2, fid_fn=fid_fn)
        fa = [r.fid for r in ta.history]
        fb = [r.fid for r in tb.history]
        assert len(fa) == len(fb) == 6
        for a, b in zip(fa, fb):
            assert (a is None) == (b is None)
            if a is not None:
                np.testing.assert_allclose(a, b, rtol=1e-4)

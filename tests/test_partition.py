"""First coverage for `data/partition.py` — the device-shard
partitioners (Section IV random equal split + the Dirichlet label-skew
ablation) and their composition with the Trainer's `partition=` hook.

Contract:
  * IID shards are equal-sized, disjoint, and drawn from the dataset
    (remainder dropped);
  * Dirichlet shards are equal-sized and label skew INCREASES as alpha
    decreases (alpha -> inf approaches the IID label mix);
  * both partitioners reproduce bitwise from their seed;
  * `Trainer(partition="dirichlet", labels=...)` shards a flat dataset
    in-engine and trains a round on the result (the non-IID regime
    composes with faults — the tentpole's partition satellite).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.partition import (partition, partition_dirichlet,
                                  partition_iid)


def make_labeled(n=120, n_classes=4, dim=6, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n)
    # encode the row index in the data so shard membership is traceable
    data = np.zeros((n, dim), np.float32)
    data[:, 0] = np.arange(n)
    data[:, 1] = labels
    return data, labels


class TestIid:
    def test_equal_disjoint_shards_cover_dataset(self):
        data, _ = make_labeled(n=103)        # remainder 3 dropped
        shards = partition_iid(data, 4, seed=1)
        assert shards.shape == (4, 25, 6)
        ids = shards[..., 0].ravel().astype(int)
        assert len(set(ids)) == 100          # disjoint
        assert set(ids) <= set(range(103))   # from the dataset

    def test_seed_reproduces_and_varies(self):
        data, _ = make_labeled()
        a = partition_iid(data, 4, seed=3)
        b = partition_iid(data, 4, seed=3)
        np.testing.assert_array_equal(a, b)
        c = partition_iid(data, 4, seed=4)
        assert (a != c).any()

    def test_shards_are_shuffled(self):
        """A contiguous-block split would leak ordering correlations;
        the shards must mix the index space."""
        data, _ = make_labeled(n=100)
        shards = partition_iid(data, 4, seed=0)
        first = shards[0, :, 0].astype(int)
        assert not np.array_equal(np.sort(first), np.arange(25))


class TestDirichlet:
    def test_equal_shards_from_dataset(self):
        data, labels = make_labeled()
        shards = partition_dirichlet(data, labels, 4, alpha=0.5, seed=0)
        assert shards.shape[0] == 4 and shards.shape[2] == 6
        assert shards.shape[1] >= 1
        ids = shards[..., 0].ravel().astype(int)
        assert len(set(ids)) == len(ids)     # disjoint
        assert set(ids) <= set(range(len(data)))

    def test_shares_bounded_by_dataset(self):
        """Equal trimming means K * n_k <= N always."""
        data, labels = make_labeled(n=90)
        shards = partition_dirichlet(data, labels, 3, alpha=1.0, seed=2)
        assert shards.shape[0] * shards.shape[1] <= 90

    def test_seed_reproduces(self):
        data, labels = make_labeled()
        a = partition_dirichlet(data, labels, 4, alpha=0.3, seed=5)
        b = partition_dirichlet(data, labels, 4, alpha=0.3, seed=5)
        np.testing.assert_array_equal(a, b)

    def test_label_skew_increases_as_alpha_decreases(self):
        """Mean per-shard label entropy: alpha=100 ~ IID mix, alpha=0.1
        concentrates shards on few classes. Averaged over seeds so the
        ordering is stable."""
        data, labels = make_labeled(n=400, n_classes=4, seed=1)

        def mean_entropy(alpha):
            ents, used = [], 0
            for seed in range(10):
                try:
                    shards = partition_dirichlet(data, labels, 4,
                                                 alpha=alpha, seed=seed)
                except AssertionError:
                    # extreme skew can starve a device entirely — the
                    # partitioner refuses those draws by design
                    continue
                used += 1
                for s in shards:
                    lab = s[:, 1].astype(int)
                    p = np.bincount(lab, minlength=4) / len(lab)
                    p = p[p > 0]
                    ents.append(-(p * np.log(p)).sum())
            assert used >= 3, f"too few viable seeds at alpha={alpha}"
            return np.mean(ents)

        assert mean_entropy(0.1) < mean_entropy(100.0)

    def test_tiny_alpha_nearly_single_class_shards(self):
        data, labels = make_labeled(n=400, n_classes=4, seed=1)
        shards = None
        for seed in range(20):   # extreme skew starves devices often
            try:
                shards = partition_dirichlet(data, labels, 4, alpha=0.01,
                                             seed=seed)
                break
            except AssertionError:
                continue
        assert shards is not None, "no viable alpha=0.01 draw in 20 seeds"
        # at alpha=0.01 most shards are dominated by one class
        dominant = []
        for s in shards:
            lab = s[:, 1].astype(int)
            dominant.append(np.bincount(lab, minlength=4).max() / len(lab))
        assert np.mean(dominant) > 0.7


class TestDispatch:
    def test_kind_dispatch_and_validation(self):
        data, labels = make_labeled()
        np.testing.assert_array_equal(
            partition(data, 4, kind="iid", seed=1),
            partition_iid(data, 4, seed=1))
        np.testing.assert_array_equal(
            partition(data, 4, labels=labels, kind="dirichlet", alpha=0.4,
                      seed=1),
            partition_dirichlet(data, labels, 4, alpha=0.4, seed=1))
        with pytest.raises(ValueError):
            partition(data, 4, kind="warp")
        with pytest.raises(AssertionError):
            partition(data, 4, kind="dirichlet")    # labels required


class TestTrainerPartitionHook:
    """`Trainer(partition=...)` shards a FLAT dataset in-engine — the
    non-IID regime composes with faults and robust reducers."""

    def _trainer(self, **kw):
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.core.channel import ChannelConfig
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        k = 4
        pcfg = ProtocolConfig(n_devices=k, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4, lr_d=1e-3, lr_g=1e-3)
        data, labels = make_labeled(n=80, dim=16)
        return Trainer(
            mlp_gan_spec(d_z=4), pcfg,
            lambda kk: mlp_gan_init(kk, d_z=4, d_hidden=8, d_data=16),
            jnp.asarray(data), jax.random.PRNGKey(0),
            channel_cfg=ChannelConfig(n_devices=k), driver="fused",
            labels=labels, **kw)

    def test_dirichlet_partition_trains_a_round(self):
        t = self._trainer(partition="dirichlet", partition_alpha=0.3,
                          partition_seed=1)
        assert t.data.shape[0] == 4          # sharded to (K, n_k, d)
        assert t.data.ndim == 3
        hist = t.run(1)
        assert len(hist) == 1
        for leaf in jax.tree_util.tree_leaves(t.state):
            assert bool(jnp.isfinite(leaf).all())

    def test_partition_matches_standalone(self):
        t = self._trainer(partition="dirichlet", partition_alpha=0.3,
                          partition_seed=7)
        data, labels = make_labeled(n=80, dim=16)
        want = partition(data, 4, labels=labels, kind="dirichlet",
                         alpha=0.3, seed=7)
        np.testing.assert_array_equal(np.asarray(t.data), want)

    def test_iid_partition_hook(self):
        t = self._trainer(partition="iid", partition_seed=2)
        assert t.data.shape[0] == 4

    def test_partition_with_faults_composes(self):
        from repro.core.faults import FaultConfig
        t = self._trainer(partition="dirichlet", partition_alpha=0.5,
                          faults=FaultConfig(n_devices=4, n_free_riders=1),
                          reducer="trimmed_mean")
        assert "fault" in t.state
        t.run(1)
        for leaf in jax.tree_util.tree_leaves(t.state):
            assert bool(jnp.isfinite(leaf).all())

    def test_pre_sharded_tree_rejects_partition(self):
        from repro.configs.base import ProtocolConfig
        from repro.core import Trainer
        from repro.core.channel import ChannelConfig
        from repro.models.gan import mlp_gan_init, mlp_gan_spec
        k = 4
        pcfg = ProtocolConfig(n_devices=k, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4)
        with pytest.raises(ValueError, match="partition"):
            Trainer(mlp_gan_spec(d_z=4), pcfg,
                    lambda kk: mlp_gan_init(kk, d_z=4, d_hidden=8,
                                            d_data=16),
                    {"x": jnp.zeros((k, 5, 16))}, jax.random.PRNGKey(0),
                    channel_cfg=ChannelConfig(n_devices=k),
                    partition="iid")

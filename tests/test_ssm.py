"""SSD scan: chunked reference vs naive step-by-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.nn.ssm import ssd_scan_ref, ssd_decode_step

KEY = jax.random.PRNGKey(0)


def naive_recurrence(x, dt, A, B, C, initial_state=None):
    """h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T; y_t = C_t h_t."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    g = B.shape[2]
    rep = h // g
    Bh = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    state = (np.zeros((b, h, n, p)) if initial_state is None
             else np.asarray(initial_state, np.float64))
    ys = np.zeros((b, s, h, p))
    for t in range(s):
        decay = np.exp(dtf[:, t] * Af)          # (b, h)
        outer = np.einsum("bhn,bhp->bhnp", Bh[:, t], xf[:, t] * dtf[:, t, :, None])
        state = decay[..., None, None] * state + outer
        ys[:, t] = np.einsum("bhn,bhnp->bhp", Ch[:, t], state)
    return ys, state


def _random_inputs(b=2, s=24, h=4, p=8, n=6, g=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, g, n))
    C = jax.random.normal(ks[4], (b, s, g, n))
    return x, dt, A, B, C


@pytest.mark.parametrize("chunk", [4, 8, 24, 128])
def test_chunked_matches_naive(chunk):
    x, dt, A, B, C = _random_inputs()
    y = ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    y_ref, _ = naive_recurrence(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)


def test_chunk_invariance():
    x, dt, A, B, C = _random_inputs(s=32)
    y1 = ssd_scan_ref(x, dt, A, B, C, chunk=4)
    y2 = ssd_scan_ref(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_final_state_and_resume():
    """Scanning two halves with carried state == scanning the whole."""
    x, dt, A, B, C = _random_inputs(s=32)
    y_full, state_full = ssd_scan_ref(x, dt, A, B, C, chunk=8,
                                      return_final_state=True)
    y1, s1 = ssd_scan_ref(x[:, :16], dt[:, :16], A, B[:, :16], C[:, :16],
                          chunk=8, return_final_state=True)
    y2, s2 = ssd_scan_ref(x[:, 16:], dt[:, 16:], A, B[:, 16:], C[:, 16:],
                          chunk=8, initial_state=s1, return_final_state=True)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.concatenate([y1, y2], 1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_full), np.asarray(s2),
                               atol=1e-4)


def test_decode_step_matches_scan():
    """Token-by-token decode must reproduce the chunked scan outputs."""
    x, dt, A, B, C = _random_inputs(s=12)
    y_scan, final = ssd_scan_ref(x, dt, A, B, C, chunk=4,
                                 return_final_state=True)
    state = jnp.zeros_like(final)
    outs = []
    for t in range(12):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                   B[:, t], C[:, t])
        outs.append(y)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_dec),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(state),
                               atol=1e-4)


def test_padding_path():
    """Non-chunk-divisible sequence lengths pad with identity steps."""
    x, dt, A, B, C = _random_inputs(s=19)
    y = ssd_scan_ref(x, dt, A, B, C, chunk=8)
    y_ref, _ = naive_recurrence(x, dt, A, B, C)
    assert y.shape == (2, 19, 4, 8)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)


def test_mixer_end_to_end_decode():
    """Full mixer: prefill state then one decode step == full forward."""
    cfg_kw = dict(d_state=8, head_dim=16, expand=2, n_groups=1)
    p = nn.ssd_mixer_init(KEY, 32, d_conv=4, **cfg_kw)
    x = jax.random.normal(KEY, (2, 9, 32))
    full = nn.ssd_mixer_apply(p, x, chunk=4, **cfg_kw)
    pre, state = nn.ssd_mixer_apply(p, x[:, :8], chunk=4,
                                    return_state=True, **cfg_kw)
    last, _ = nn.ssd_mixer_apply(p, x[:, 8:9], state=state, **cfg_kw)
    np.testing.assert_allclose(np.asarray(full[:, 8:9]), np.asarray(last),
                               atol=2e-4)

"""Property tests: the robust reducers (`kernels/robust_avg`) against
their numpy `ref.py` twins, over random payload matrices, dtypes,
BLOCK_N-edge sizes, and participation masks.

Contract (see kernels/robust_avg/ops.py):
  * every reducer agrees with its numpy reference on arbitrary (K, N)
    payloads and nonnegative weight vectors with zeros (dropped /
    unscheduled workers);
  * identity regimes degrade to the plain weighted average EXACTLY —
    trimmed_mean(trim=0), norm_clip with a huge clip factor, and
    krum(f=0) all reproduce `wavg` (the zero-faults path costs nothing
    and changes nothing);
  * the tree-level wrapper (`averaging.weighted_average(robust=...)`)
    preserves structure, shape, and dtype while flattening through the
    ONE robust reduction;
  * robustness does what it claims: an outlier row with enough honest
    mass is rejected by trimmed_mean/krum where the plain mean moves.

Hypothesis runs when importable (guarded like
tests/test_averaging_property.py); the same check functions run on
seeded twins unconditionally.
"""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.averaging import weighted_average
from repro.kernels.robust_avg import RobustConfig, ref as robust_ref
from repro.kernels.robust_avg.ops import (clip_weights, krum_weights,
                                          robust_average)
from repro.kernels.wavg.kernel import BLOCK_N

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def make_case(seed: int, *, k=None, n=None, zero_weights=False):
    """Random (K, N) payload + weights, fully determined by `seed`."""
    rng = np.random.default_rng(seed)
    k = k or int(rng.integers(2, 10))
    n = n or int(rng.integers(1, 400))
    x = rng.standard_normal((k, n)).astype(np.float32) * rng.uniform(0.1, 8.0)
    if zero_weights:
        w = np.zeros(k, np.float32)
    else:
        w = rng.uniform(0.2, 5.0, k).astype(np.float32)
        # participation mask: some workers dropped (weight exactly 0),
        # like the scheduler/dropout output — keep >= 1 participant
        drop = rng.uniform(size=k) < 0.3
        drop[int(rng.integers(k))] = False
        w = np.where(drop, 0.0, w)
    return x, w


def random_config(seed: int) -> RobustConfig:
    rng = np.random.default_rng(seed ^ 0xC0FFEE)
    method = ("trimmed_mean", "norm_clip", "krum")[int(rng.integers(3))]
    return RobustConfig(method=method, trim=int(rng.integers(0, 3)),
                        clip_factor=float(rng.uniform(0.5, 4.0)),
                        krum_f=int(rng.integers(0, 3)))


# ---------------------------------------------------------------------------
# Shared checks
# ---------------------------------------------------------------------------

def plain_avg(x, w):
    """Normalized weighted mean in float64 — what `wavg` computes after
    `averaging._normalized` (the kernel's `wavg_ref` expects weights
    already normalized, so the twin lives here)."""
    w = np.asarray(w, np.float64)
    wn = w / max(w.sum(), 1e-12)
    return np.einsum("k,kn->n", wn, np.asarray(x, np.float64))


def check_matches_ref(x, w, cfg: RobustConfig, atol=2e-5):
    got = np.asarray(robust_average(jnp.asarray(x), jnp.asarray(w), cfg))
    want = robust_ref.robust_ref(np.asarray(x, np.float64),
                                 np.asarray(w, np.float64), cfg)
    np.testing.assert_allclose(got, want.astype(np.float32), atol=atol)


def check_identity_regime(x, w):
    """trim=0 / huge clip / f=0 must equal the plain wavg reference."""
    want = plain_avg(x, w).astype(np.float32)
    for cfg in (RobustConfig(method="trimmed_mean", trim=0),
                RobustConfig(method="norm_clip", clip_factor=1e9),
                RobustConfig(method="krum", krum_f=0)):
        got = np.asarray(robust_average(jnp.asarray(x), jnp.asarray(w), cfg))
        np.testing.assert_allclose(got, want, atol=2e-5,
                                   err_msg=f"identity regime {cfg.method}")


# ---------------------------------------------------------------------------
# Hypothesis property tests
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    SETTINGS = dict(max_examples=20, deadline=None)

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16))
    def test_prop_reducers_match_ref(seed):
        x, w = make_case(seed)
        check_matches_ref(x, w, random_config(seed))

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16), blocks=st.integers(1, 2),
           off=st.integers(-2, 2))
    def test_prop_reducers_match_ref_at_block_edges(seed, blocks, off):
        n = max(1, blocks * BLOCK_N + off)
        x, w = make_case(seed, n=n)
        check_matches_ref(x, w, random_config(seed))

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2 ** 16))
    def test_prop_identity_regimes_equal_wavg(seed):
        x, w = make_case(seed)
        check_identity_regime(x, w)


# ---------------------------------------------------------------------------
# Seeded twins (always run)
# ---------------------------------------------------------------------------

class TestRobustReducersSeeded:
    @pytest.mark.parametrize("method", ["trimmed_mean", "norm_clip",
                                        "krum"])
    @pytest.mark.parametrize("seed", range(4))
    def test_matches_ref_random_payloads(self, method, seed):
        x, w = make_case(seed)
        check_matches_ref(x, w, RobustConfig(method=method, trim=1,
                                             clip_factor=1.5, krum_f=1))

    @pytest.mark.parametrize("method", ["trimmed_mean", "norm_clip",
                                        "krum"])
    @pytest.mark.parametrize("blocks", [1, 2])
    def test_matches_ref_at_block_edges(self, method, blocks):
        for off in (-1, 0, 1):
            x, w = make_case(blocks * 7 + off + 1,
                             n=max(1, blocks * BLOCK_N + off))
            check_matches_ref(x, w, RobustConfig(method=method))

    @pytest.mark.parametrize("seed", range(3))
    def test_identity_regimes_equal_wavg(self, seed):
        check_identity_regime(*make_case(seed))

    def test_all_honest_uniform_weights_equal_wavg(self):
        """With no outliers and equal weights, trimming symmetric noise
        stays near the mean and clip/krum keep everyone — all three
        land on (or near) the plain average."""
        rng = np.random.default_rng(0)
        x = rng.standard_normal((8, 64)).astype(np.float32)
        w = np.ones(8, np.float32)
        mean = x.mean(0)
        for cfg in (RobustConfig(method="norm_clip", clip_factor=1e9),
                    RobustConfig(method="krum", krum_f=0)):
            got = np.asarray(robust_average(jnp.asarray(x),
                                            jnp.asarray(w), cfg))
            np.testing.assert_allclose(got, mean, atol=2e-5)

    def test_zero_participants_guarded(self):
        """All weights zero (straggler-only round): finite output, both
        impl and ref."""
        x, w = make_case(5, k=4, zero_weights=True)
        for method in ("trimmed_mean", "norm_clip", "krum"):
            cfg = RobustConfig(method=method)
            got = np.asarray(robust_average(jnp.asarray(x),
                                            jnp.asarray(w), cfg))
            assert np.isfinite(got).all()
            ref = robust_ref.robust_ref(np.asarray(x, np.float64),
                                        np.asarray(w, np.float64), cfg)
            np.testing.assert_allclose(got, ref.astype(np.float32),
                                       atol=2e-5)

    def test_dropped_rows_never_contribute(self):
        """A zero-weight row full of garbage must not move any reducer
        (participation masks gate the robust statistics too)."""
        rng = np.random.default_rng(7)
        x = rng.standard_normal((6, 96)).astype(np.float32)
        w = np.array([1, 1, 1, 1, 1, 0], np.float32)
        x_garbage = x.copy()
        x_garbage[5] = 1e6
        for method in ("trimmed_mean", "norm_clip", "krum"):
            cfg = RobustConfig(method=method, trim=1, krum_f=1)
            a = np.asarray(robust_average(jnp.asarray(x),
                                          jnp.asarray(w), cfg))
            b = np.asarray(robust_average(jnp.asarray(x_garbage),
                                          jnp.asarray(w), cfg))
            np.testing.assert_allclose(a, b, atol=1e-6)

    def test_outlier_rejected_where_mean_moves(self):
        """The point of the exercise: one 100x outlier among 7 honest
        rows shifts the plain mean but not trimmed_mean or krum."""
        rng = np.random.default_rng(11)
        honest = rng.standard_normal((8, 128)).astype(np.float32)
        attacked = honest.copy()
        attacked[3] = 100.0
        w = np.ones(8, np.float32)
        honest_mean = honest[np.arange(8) != 3].mean(0)
        plain = plain_avg(attacked, w)
        assert np.abs(plain - honest_mean).max() > 1.0
        for cfg in (RobustConfig(method="trimmed_mean", trim=1),
                    RobustConfig(method="krum", krum_f=1)):
            got = np.asarray(robust_average(jnp.asarray(attacked),
                                            jnp.asarray(w), cfg))
            assert np.abs(got - honest_mean).max() < 1.0, cfg.method

    def test_config_validation(self):
        with pytest.raises(ValueError):
            RobustConfig(method="warp")
        with pytest.raises(ValueError):
            RobustConfig(method="trimmed_mean", trim=-1)
        with pytest.raises(ValueError):
            RobustConfig(method="norm_clip", clip_factor=0.0)
        with pytest.raises(ValueError):
            RobustConfig(method="krum", krum_f=-1)


class TestWeightVectorReducers:
    """norm_clip / krum compute EFFECTIVE weight vectors reduced by the
    existing wavg kernel — pin the weight-vector semantics directly."""

    def test_clip_weights_scale_bounded(self):
        x, w = make_case(9, k=6, n=200)
        w_eff = np.asarray(clip_weights(jnp.asarray(x), jnp.asarray(w),
                                        clip_factor=1.0))
        assert w_eff.shape == (6,)
        # normalized by the ORIGINAL weight total: clipped rows shrink
        # the aggregate toward zero, so the sum is <= 1, == 1 iff
        # nothing clipped
        assert w_eff.sum() <= 1.0 + 1e-5
        unclipped = np.asarray(clip_weights(jnp.asarray(x),
                                            jnp.asarray(w),
                                            clip_factor=1e9))
        np.testing.assert_allclose(unclipped.sum(), 1.0, atol=1e-5)
        # dropped workers stay dropped
        np.testing.assert_array_equal(w_eff[w == 0], 0.0)

    def test_krum_weights_select_subset(self):
        x, w = make_case(10, k=8, n=100)
        w = np.ones(8, np.float32)
        w_eff = np.asarray(krum_weights(jnp.asarray(x), jnp.asarray(w),
                                        f=2, m=None))
        sel = robust_ref.krum_selection_ref(np.asarray(x, np.float64),
                                            w.astype(np.float64), f=2,
                                            m=None)
        np.testing.assert_array_equal(w_eff > 0, sel)
        np.testing.assert_allclose(w_eff.sum(), 1.0, atol=1e-5)


class TestTreeLevelRobustAverage:
    """`averaging.weighted_average(..., robust=...)`: the stacked-layout
    entry point — structure/shape/dtype preserved through the one
    flatten -> robust reduction -> unflatten round trip."""

    def make_tree(self, seed, k=6):
        rng = np.random.default_rng(seed)
        return {
            "a": jnp.asarray(rng.standard_normal((k, 3, 5)), jnp.float32),
            "b": {"c": jnp.asarray(rng.standard_normal((k, 7)),
                                   jnp.bfloat16)},
        }, jnp.asarray(rng.uniform(0.5, 2.0, k), jnp.float32)

    @pytest.mark.parametrize("method", ["trimmed_mean", "norm_clip",
                                        "krum"])
    def test_structure_and_dtype_roundtrip(self, method):
        tree, w = self.make_tree(0)
        out = weighted_average(tree, w, robust=RobustConfig(method=method))
        assert (jax.tree_util.tree_structure(out)
                == jax.tree_util.tree_structure(
                    jax.tree.map(lambda x: x[0], tree)))
        assert out["a"].shape == (3, 5) and out["a"].dtype == jnp.float32
        assert out["b"]["c"].shape == (7,)
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_identity_regime_matches_plain_tree_average(self):
        tree, w = self.make_tree(1)
        plain = weighted_average(tree, w)
        robust = weighted_average(
            tree, w, robust=RobustConfig(method="trimmed_mean", trim=0))
        for a, b in zip(jax.tree_util.tree_leaves(plain),
                        jax.tree_util.tree_leaves(robust)):
            atol = 1e-5 if a.dtype == jnp.float32 else 0.02
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=atol)

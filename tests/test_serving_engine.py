"""Batched serving engine: correctness against step-by-step decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch_config
from repro.models import gan
from repro.serving import ServingEngine, Request

KEY = jax.random.PRNGKey(0)


def greedy_reference(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    for _ in range(n_new):
        out = gan.generator_lm_apply(params, cfg, toks, mode="train",
                                     remat=False)
        nxt = jnp.argmax(out["logits"][:, -1:], -1)
        toks = jnp.concatenate([toks, nxt], axis=1)
    return np.asarray(toks[0, len(prompt):])


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-130m"])
def test_engine_matches_reference(name):
    cfg = get_arch_config(name).reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 7, 3)]
    n_new = 5

    engine = ServingEngine(cfg, params, batch_size=2, max_len=32)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = engine.run()
    assert len(finished) == 3
    for req in finished:
        ref = greedy_reference(cfg, params, req.prompt, n_new)
        np.testing.assert_array_equal(np.asarray(req.out_tokens), ref,
                                      err_msg=f"request {req.rid}")


def test_more_requests_than_slots_all_complete():
    cfg = get_arch_config("granite-3-2b").reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(1)
    engine = ServingEngine(cfg, params, batch_size=2, max_len=24)
    for i in range(5):
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab, 4).astype(
                                  np.int32),
                              max_new_tokens=3))
    finished = engine.run()
    assert sorted(r.rid for r in finished) == list(range(5))
    assert all(len(r.out_tokens) == 3 for r in finished)

"""Batched serving engine: correctness against step-by-step decoding,
the mixed-workload matrix (staggered admissions at distinct positions,
chunked prefill interleaved with decode, paged-vs-dense equivalence),
and the host-side scheduling contracts (FIFO admission, rejection path,
prefill compile-count bound)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch_config
from repro.models import gan
from repro.serving import ServingEngine, Request

KEY = jax.random.PRNGKey(0)


def greedy_reference(cfg, params, prompt, n_new):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    for _ in range(n_new):
        out = gan.generator_lm_apply(params, cfg, toks, mode="train",
                                     remat=False)
        nxt = jnp.argmax(out["logits"][:, -1:], -1)
        toks = jnp.concatenate([toks, nxt], axis=1)
    return np.asarray(toks[0, len(prompt):])


@pytest.mark.parametrize("name", ["qwen3-1.7b", "mamba2-130m"])
def test_engine_matches_reference(name):
    cfg = get_arch_config(name).reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 7, 3)]
    n_new = 5

    engine = ServingEngine(cfg, params, batch_size=2, max_len=32)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = engine.run()
    assert len(finished) == 3
    for req in finished:
        ref = greedy_reference(cfg, params, req.prompt, n_new)
        np.testing.assert_array_equal(np.asarray(req.out_tokens), ref,
                                      err_msg=f"request {req.rid}")


def test_more_requests_than_slots_all_complete():
    cfg = get_arch_config("granite-3-2b").reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(1)
    engine = ServingEngine(cfg, params, batch_size=2, max_len=24)
    for i in range(5):
        engine.submit(Request(rid=i,
                              prompt=rng.integers(0, cfg.vocab, 4).astype(
                                  np.int32),
                              max_new_tokens=3))
    finished = engine.run()
    assert sorted(r.rid for r in finished) == list(range(5))
    assert all(len(r.out_tokens) == 3 for r in finished)


def test_staggered_admissions_decode_in_a_single_step():
    """The ISSUE regression test: slots admitted at different times sit
    at DISTINCT positions, and one step() — one jitted dispatch — must
    advance all of them at once (no position grouping, no head-of-line
    blocking). Also pins prefill-during-decode: the same dispatch that
    prefills a new slot's chunk keeps every decoding slot moving."""
    cfg = get_arch_config("qwen3-1.7b").reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (4, 9, 6)]

    engine = ServingEngine(cfg, params, batch_size=3, max_len=48,
                           block_size=8, prefill_chunk=4)
    engine.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=12))
    for _ in range(5):          # r0 prefills (1 chunk) and decodes ahead
        assert engine.step()

    # stagger: admit r1/r2 while r0 is mid-decode
    engine.submit(Request(rid=1, prompt=prompts[1], max_new_tokens=12))
    engine.submit(Request(rid=2, prompt=prompts[2], max_new_tokens=12))
    r0 = engine.slots[0].req
    before = len(r0.out_tokens)
    d0 = engine.dispatch_count
    while not all(s is not None and s.prefilled for s in engine.slots):
        assert engine.step()    # r1/r2 prefill chunks ride along
    # prefill-during-decode: r0 kept emitting one token per dispatch
    assert len(r0.out_tokens) - before == engine.dispatch_count - d0

    # all three slots now decode at DISTINCT positions...
    positions = [s.pos for s in engine.slots]
    assert len(set(positions)) == 3
    counts = [len(s.req.out_tokens) for s in engine.slots]
    d0 = engine.dispatch_count
    assert engine.step()
    # ...and ONE dispatch advanced every one of them by exactly 1 token
    assert engine.dispatch_count == d0 + 1
    assert [len(s.req.out_tokens) for s in engine.slots] == \
        [c + 1 for c in counts]
    assert [s.pos for s in engine.slots] == [p + 1 for p in positions]

    finished = engine.run()
    assert sorted(r.rid for r in finished) == [0, 1, 2]
    for req in finished:        # staggering never changes the tokens
        ref = greedy_reference(cfg, params, req.prompt, 12)
        np.testing.assert_array_equal(np.asarray(req.out_tokens), ref,
                                      err_msg=f"request {req.rid}")


@pytest.mark.parametrize("name", ["qwen3-1.7b", "gemma3-12b"])
def test_mixed_workload_paged_matches_dense(name):
    """Mixed prompt lengths and temperatures through BOTH cache
    backends: the paged engine must emit bitwise-identical token streams
    (sampling is keyed by (seed, rid, token_index), so the backend can
    never leak into the output), and the greedy requests must match the
    full-forward reference."""
    cfg = get_arch_config(name).reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(3)
    workload = [(rng.integers(0, cfg.vocab,
                              int(rng.integers(2, 14))).astype(np.int32),
                 int(rng.integers(2, 7)), temp)
                for temp in (0.0, 0.8, 0.0, 0.8, 0.0)]

    outs = {}
    for block in (None, 8):
        engine = ServingEngine(cfg, params, batch_size=2, max_len=32,
                               block_size=block, prefill_chunk=4, seed=7)
        for i, (p, n, t) in enumerate(workload):
            engine.submit(Request(rid=i, prompt=p, max_new_tokens=n,
                                  temperature=t))
        finished = engine.run()
        assert len(finished) == len(workload)
        outs[block] = {r.rid: list(r.out_tokens) for r in finished}
    assert outs[None] == outs[8]            # paged == dense, bitwise

    for i, (p, n, t) in enumerate(workload):
        if t == 0.0:
            ref = greedy_reference(cfg, params, p, n)
            np.testing.assert_array_equal(np.asarray(outs[8][i]), ref,
                                          err_msg=f"request {i}")


def test_rejection_path_keeps_engine_running():
    """Requests that can never fit are marked failed with a reason and
    the engine serves everyone else — no assert, no dead engine."""
    cfg = get_arch_config("granite-3-2b").reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(4)
    ok = lambda rid: Request(
        rid=rid, prompt=rng.integers(0, cfg.vocab, 4).astype(np.int32),
        max_new_tokens=3)
    engine = ServingEngine(cfg, params, batch_size=2, max_len=16)
    engine.submit(ok(0))
    engine.submit(Request(rid=1, prompt=rng.integers(
        0, cfg.vocab, 20).astype(np.int32), max_new_tokens=8))  # 28 > 16
    engine.submit(Request(rid=2, prompt=np.zeros(0, np.int32)))
    engine.submit(ok(3))
    finished = engine.run()
    assert sorted(r.rid for r in finished) == [0, 3]
    assert [r.rid for r in engine.rejected] == [1, 2]
    assert "max_len" in engine.rejected[0].failed
    assert "empty" in engine.rejected[1].failed
    assert all(not r.done for r in engine.rejected)


def test_admission_is_fifo_by_submission_order():
    """deque admission: with one slot, requests are served strictly in
    submission order (rid order), whatever their sizes."""
    cfg = get_arch_config("granite-3-2b").reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(5)
    engine = ServingEngine(cfg, params, batch_size=1, max_len=32,
                           block_size=8)
    for i, n in enumerate((9, 2, 13, 5)):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
            max_new_tokens=2))
    finished = engine.run()
    assert [r.rid for r in finished] == [0, 1, 2, 3]


def test_prefill_compile_count_is_log_bounded():
    """Power-of-two prefill buckets: any mix of prompt lengths compiles
    at most 1 (decode-only) + log2(prefill_chunk) + 1 step programs."""
    cfg = get_arch_config("granite-3-2b").reduced()
    params = gan.generator_init(KEY, cfg)
    rng = np.random.default_rng(6)
    chunk = 8
    engine = ServingEngine(cfg, params, batch_size=2, max_len=64,
                           block_size=8, prefill_chunk=chunk)
    for i, n in enumerate((1, 2, 3, 5, 7, 9, 12, 17, 23)):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, n).astype(np.int32),
            max_new_tokens=2))
    finished = engine.run()
    assert len(finished) == 9
    bound = 1 + int(np.log2(chunk)) + 1          # {None, 1, 2, 4, 8}
    assert engine.compile_count <= bound


def test_tp_construction_guards():
    """Fast-lane: MoE and fuse_proj configs must refuse tensor-parallel
    serving up front (mirrors models/specs.py), single device is enough
    to hit both."""
    params = None
    cfg = get_arch_config("mixtral-8x22b").reduced()
    with pytest.raises(ValueError, match="MoE"):
        ServingEngine(cfg, params, tp=2)
    cfg = dataclasses.replace(get_arch_config("qwen3-1.7b").reduced(),
                              fuse_proj=True)
    with pytest.raises(ValueError, match="fuse_proj"):
        ServingEngine(cfg, params, tp=2)

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.averaging import weighted_average
from repro.core.channel import ChannelConfig, ChannelSimulator, round_wallclock
from repro.core.jax_scheduling import JaxScheduler, schedule_step
from repro.core.scheduling import SchedulerState, schedule_round

POLICIES = ("all", "round_robin", "best_channel", "prop_fair", "random")


def _sim(**kw):
    return ChannelSimulator(ChannelConfig(n_devices=10, seed=3, **kw))


class TestChannel:
    def test_path_loss_monotone_in_distance(self):
        sim = _sim()
        order = np.argsort(sim.dist_km)
        pl = sim.path_loss_db()
        assert (np.diff(pl[order]) >= 0).all()

    def test_rates_positive_and_fewer_devices_faster(self):
        sim = _sim(fading=False)
        r_all = sim.uplink_rates(10)
        r_half = sim.uplink_rates(5)
        assert (r_all > 0).all()
        assert (r_half > r_all).all()   # more bandwidth each

    def test_straggler_deadline(self):
        sim = _sim(straggler_deadline_s=1e-9)
        mask = np.ones(10, dtype=bool)
        t = sim.round_timing(mask=mask, disc_params=10_000, gen_params=10_000,
                             disc_step_flops=1e9, gen_step_flops=1e9,
                             n_d=5, n_g=5)
        assert t.stragglers.all()

    def test_wallclock_serial_vs_parallel(self):
        """One serial round takes at least as long as one parallel round
        (device compute is not overlapped with the server's)."""
        sim = _sim(fading=False)
        mask = np.ones(10, dtype=bool)
        t = sim.round_timing(mask=mask, disc_params=2_765_568,
                             gen_params=3_576_704, disc_step_flops=1e10,
                             gen_step_flops=1e10, n_d=5, n_g=5)
        w_par = round_wallclock(t, mask, schedule="parallel")
        w_ser = round_wallclock(t, mask, schedule="serial")
        assert w_ser >= w_par > 0

    def test_fedgan_round_longer_than_proposed(self):
        """FedGAN: ~2x device compute and 2x upload bytes per round."""
        sim = _sim(fading=False)
        mask = np.ones(10, dtype=bool)
        kw = dict(mask=mask, disc_params=2_765_568, gen_params=3_576_704,
                  disc_step_flops=1e10, gen_step_flops=1e10, n_d=5, n_g=5)
        t_prop = sim.round_timing(**kw)
        t_fed = sim.round_timing(fedgan=True, **kw)
        w_prop = round_wallclock(t_prop, mask, schedule="serial")
        w_fed = round_wallclock(t_fed, mask, schedule="serial", fedgan=True)
        assert w_fed > w_prop


class TestScheduling:
    def test_all(self):
        st = SchedulerState("all", 10)
        rng = np.random.default_rng(0)
        assert schedule_round(st, np.ones(10), rng).all()

    def test_round_robin_covers_everyone(self):
        st = SchedulerState("round_robin", 10, ratio=0.3)
        rng = np.random.default_rng(0)
        seen = np.zeros(10, dtype=bool)
        for _ in range(5):
            seen |= schedule_round(st, np.ones(10), rng)
        assert seen.all()

    def test_best_channel_picks_top(self):
        st = SchedulerState("best_channel", 10, ratio=0.2)
        rng = np.random.default_rng(0)
        rates = np.arange(10.0)
        mask = schedule_round(st, rates, rng)
        assert mask[8] and mask[9] and mask.sum() == 2

    def test_ratio_counts(self):
        for ratio, expect in [(1.0, 10), (0.5, 5), (0.2, 2), (0.05, 1)]:
            st = SchedulerState("random", 10, ratio=ratio)
            rng = np.random.default_rng(0)
            assert schedule_round(st, np.ones(10), rng).sum() == expect

    def test_prop_fair_rotates_under_equal_rates(self):
        """With equal instantaneous rates, served devices' EWMA rises so
        priority shifts to unserved ones."""
        st = SchedulerState("prop_fair", 4, ratio=0.5)
        rng = np.random.default_rng(0)
        m1 = schedule_round(st, np.ones(4), rng)
        m2 = schedule_round(st, np.ones(4), rng)
        assert (m1 != m2).any()

    def test_unknown_policy_raises(self):
        st = SchedulerState("nope", 4)
        with pytest.raises(ValueError):
            schedule_round(st, np.ones(4), np.random.default_rng(0))


class TestSeededInvariants:
    """Seeded property tests (hypothesis-free) over both scheduler twins
    — the invariants Figs. 3-6 lean on."""

    @pytest.mark.parametrize("policy", POLICIES)
    def test_mask_has_exactly_n_scheduled(self, policy):
        for seed in range(8):
            rng = np.random.default_rng(seed)
            k = int(rng.integers(2, 12))
            ratio = float(rng.uniform(0.05, 1.0))
            np_state = SchedulerState(policy, k, ratio=ratio)
            jx = JaxScheduler(policy=policy, n_devices=k, ratio=ratio)
            carry = jx.init_carry()
            n = np_state.n_scheduled
            assert n == jx.n_scheduled == max(1, math.ceil(ratio * k))
            expect = k if policy == "all" else n   # "all" ignores ratio
            for t in range(4):
                rates = rng.uniform(0.1, 9.0, k)
                np_mask = schedule_round(np_state, rates, rng)
                jx_mask, carry = schedule_step(
                    jx, carry, jnp.asarray(rates, jnp.float32),
                    jax.random.fold_in(jax.random.PRNGKey(seed), t))
                assert np_mask.sum() == expect
                assert int(np.asarray(jx_mask).sum()) == expect

    def test_round_robin_covers_all_devices_in_ceil_k_over_n_rounds(self):
        for seed, (k, ratio) in enumerate([(10, 0.3), (7, 0.5), (5, 0.2),
                                           (8, 1.0), (9, 0.34)]):
            rng = np.random.default_rng(seed)
            np_state = SchedulerState("round_robin", k, ratio=ratio)
            jx = JaxScheduler(policy="round_robin", n_devices=k,
                              ratio=ratio)
            carry = jx.init_carry()
            budget = math.ceil(k / np_state.n_scheduled)
            seen_np = np.zeros(k, dtype=bool)
            seen_jx = np.zeros(k, dtype=bool)
            for t in range(budget):
                rates = rng.uniform(0.1, 9.0, k)
                seen_np |= schedule_round(np_state, rates, rng)
                m, carry = schedule_step(
                    jx, carry, jnp.asarray(rates, jnp.float32),
                    jax.random.fold_in(jax.random.PRNGKey(seed), t))
                seen_jx |= np.asarray(m)
            assert seen_np.all() and seen_jx.all()

    def test_zero_weight_devices_never_affect_weighted_average(self):
        """Algorithm 2: a zero-weight replica is a strict no-op no matter
        how corrupt its parameters are (straggler/unscheduled contract)."""
        for seed in range(10):
            rng = np.random.default_rng(seed)
            k = int(rng.integers(2, 7))
            base = jnp.asarray(rng.standard_normal((k, 5)), jnp.float32)
            w = jnp.asarray(rng.uniform(0.5, 3.0, k), jnp.float32)
            avg1 = weighted_average({"p": base}, w)["p"]
            poison = float(rng.uniform(1e3, 1e6))
            extra = jnp.concatenate([base, poison * jnp.ones((1, 5))])
            w2 = jnp.concatenate([w, jnp.zeros(1)])
            avg2 = weighted_average({"p": extra}, w2)["p"]
            np.testing.assert_allclose(np.asarray(avg1), np.asarray(avg2),
                                       atol=1e-5)

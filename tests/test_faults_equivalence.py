"""Fault-injection determinism matrix: the hostile-worker layer
(core/faults.py) must realize IDENTICAL fault programs on every engine.

Contract (see core/faults.py docstring):
  * dropout masks and byzantine/free-rider roles are pure functions of
    (FaultConfig, round_key) — the SAME round-key machinery as
    `protocol.schedule_and_time` — so the host oracle, the stacked
    fused scan, and the mesh `shard_rounds_scan` draw BITWISE-identical
    fault realizations (satellite mirror of test_driver_equivalence);
  * with faults on, params still agree across drivers to float32
    round-off and wallclock to rtol 1e-5 with fading off (stragglers
    and free-rider zero-compute flow through the SAME channel model);
  * with zero faults, a FaultConfig-carrying trainer reproduces the
    no-faults trajectory exactly (the fault layer is a no-op, not a
    perturbation);
  * checkpoint resume under faults continues the stale-upload cache,
    masks, and wallclock exactly (satellite: the fault state rides in
    checkpoints).

The 8-device mesh matrix is `slow`/`robust`-marked and runs in CI's
robust lane; the K=4 host-vs-stacked-fused checks stay in the fast lane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.core.channel import ChannelConfig
from repro.core.faults import FaultConfig, FaultProgram, fault_program
from repro.kernels.robust_avg import RobustConfig
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)
CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
SPEC = make_dcgan_spec(CFG)
K = 4
DATA = jax.random.normal(jax.random.PRNGKey(9), (K, 8, 8, 8, 1))

FULL_FAULTS = FaultConfig(n_devices=K, dropout_prob=0.3, n_free_riders=1,
                          n_byzantine=1, straggler_factor=2.0, seed=1)


def make_trainer(driver, *, faults=None, reducer=None,
                 algorithm="proposed", schedule="serial", bits=16):
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                          schedule=schedule, scheduler="round_robin",
                          scheduling_ratio=0.5, quantize_bits=bits)
    chan = ChannelConfig(n_devices=K, seed=3, fading=False)
    return Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), DATA, KEY,
                   channel_cfg=chan, driver=driver, algorithm=algorithm,
                   faults=faults, reducer=reducer)


def assert_trees_close(a, b, atol=2e-5):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


def assert_run_pair_matches(th, tf, rounds=5):
    h, f = th.run(rounds), tf.run(rounds)
    for rh, rf in zip(h, f):
        np.testing.assert_array_equal(rh.mask, rf.mask)       # bitwise
        np.testing.assert_allclose(rh.wallclock_s, rf.wallclock_s,
                                   rtol=1e-5)
    assert_trees_close(th.state, tf.state)
    return h, f


class TestFaultProgramDeterminism:
    """The program itself, independent of any engine."""

    def test_roles_reproduce_and_are_disjoint(self):
        cfg = FaultConfig(n_devices=8, n_free_riders=2, n_byzantine=3,
                          straggler_factor=4.0, seed=7)
        a, b = FaultProgram(cfg), FaultProgram(cfg)
        np.testing.assert_array_equal(a.free_rider_np, b.free_rider_np)
        np.testing.assert_array_equal(a.byzantine_np, b.byzantine_np)
        np.testing.assert_array_equal(a.compute_mult_np, b.compute_mult_np)
        assert not (a.free_rider_np & a.byzantine_np).any()
        assert a.free_rider_np.sum() == 2 and a.byzantine_np.sum() == 3
        # free-riders spend no compute; stragglers in [1, factor]
        assert (a.compute_mult_np[a.free_rider_np] == 0.0).all()
        honest = ~a.free_rider_np
        assert (a.compute_mult_np[honest] >= 1.0).all()
        assert (a.compute_mult_np[honest] <= 4.0).all()

    def test_different_seed_different_roles(self):
        kw = dict(n_devices=8, n_free_riders=2, n_byzantine=2)
        a = FaultProgram(FaultConfig(seed=0, **kw))
        b = FaultProgram(FaultConfig(seed=1, **kw))
        assert (a.free_rider_np != b.free_rider_np).any() or \
            (a.byzantine_np != b.byzantine_np).any()

    def test_dropout_mask_pure_in_round_key(self):
        prog = fault_program(FaultConfig(n_devices=6, dropout_prob=0.5))
        rk = jax.random.fold_in(KEY, 3)
        np.testing.assert_array_equal(prog.dropout_mask_np(rk),
                                      prog.dropout_mask_np(rk))
        # distinct rounds realize distinct masks (w.h.p. at p=0.5, K=6,
        # over 8 rounds)
        masks = [prog.dropout_mask_np(jax.random.fold_in(KEY, t))
                 for t in range(8)]
        assert any((masks[0] != m).any() for m in masks[1:])

    def test_dropout_mask_jnp_np_twins_bitwise(self):
        prog = fault_program(FaultConfig(n_devices=6, dropout_prob=0.4))
        for t in range(5):
            rk = jax.random.fold_in(KEY, t)
            np.testing.assert_array_equal(
                np.asarray(prog.dropout_mask(rk)), prog.dropout_mask_np(rk))

    def test_config_validation(self):
        # dropout_prob=1.0 (every worker drops every round) is LEGAL —
        # the no-survivor round keeps the previous global
        # (tests/test_no_survivor.py); only out-of-range values raise.
        FaultConfig(n_devices=4, dropout_prob=1.0)
        with pytest.raises(ValueError, match="dropout_prob"):
            FaultConfig(n_devices=4, dropout_prob=1.5)
        with pytest.raises(ValueError, match="exceed"):
            FaultConfig(n_devices=4, n_free_riders=3, n_byzantine=2)
        with pytest.raises(ValueError, match="straggler"):
            FaultConfig(n_devices=4, straggler_factor=0.5)


class TestHostVsFusedUnderFaults:
    """K=4 fast lane: host oracle vs stacked fused under the full fault
    program — masks bitwise, wallclock rtol 1e-5, params atol 2e-5."""

    @pytest.mark.parametrize("algorithm", ["proposed", "fedgan"])
    def test_full_fault_program_matches(self, algorithm):
        th = make_trainer("host", faults=FULL_FAULTS, algorithm=algorithm)
        tf = make_trainer("fused", faults=FULL_FAULTS, algorithm=algorithm)
        h, _ = assert_run_pair_matches(th, tf)
        # dropout actually drops someone beyond the scheduler's choice
        # at p=0.3 over 5 rounds of 2 scheduled (w.h.p.)
        assert any(r.mask.sum() < 2 for r in h)

    @pytest.mark.parametrize("reducer", ["trimmed_mean", "norm_clip",
                                         "krum"])
    def test_faults_with_robust_reducer_matches(self, reducer):
        rc = RobustConfig(method=reducer, trim=1, krum_f=1)
        th = make_trainer("host", faults=FULL_FAULTS, reducer=rc)
        tf = make_trainer("fused", faults=FULL_FAULTS, reducer=rc)
        assert_run_pair_matches(th, tf)

    def test_zero_fault_config_is_identity(self):
        """A FaultConfig with every axis off must reproduce the
        no-faults trajectory bitwise (same jitted math, no-op layer)."""
        t0 = make_trainer("fused")
        t1 = make_trainer("fused", faults=FaultConfig(n_devices=K))
        h0, h1 = t0.run(4), t1.run(4)
        for r0, r1 in zip(h0, h1):
            np.testing.assert_array_equal(r0.mask, r1.mask)
            assert r0.wallclock_s == r1.wallclock_s
        for a, b in zip(jax.tree_util.tree_leaves(t0.state),
                        jax.tree_util.tree_leaves(t1.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_straggler_factor_stretches_wallclock(self):
        """compute_mult really reaches channel timing: a 10x straggler
        fleet must be slower than the honest fleet on both drivers."""
        slow_cfg = FaultConfig(n_devices=K, straggler_factor=10.0, seed=2)
        for driver in ("host", "fused"):
            fast = make_trainer(driver)
            slow = make_trainer(driver, faults=slow_cfg)
            wf = sum(r.wallclock_s for r in fast.run(3))
            ws = sum(r.wallclock_s for r in slow.run(3))
            assert ws > wf

    def test_free_riders_degrade_plain_mean(self):
        """The attack does damage: 2-of-4 free-riders under the plain
        mean must change the trajectory vs the honest run (otherwise
        the robustness matrix is testing a no-op)."""
        honest = make_trainer("fused")
        attacked = make_trainer(
            "fused", faults=FaultConfig(n_devices=K, n_free_riders=2))
        honest.run(3), attacked.run(3)
        la = jax.tree_util.tree_leaves(honest.state["disc"])
        lb = jax.tree_util.tree_leaves(attacked.state["disc"])
        assert any(float(jnp.abs(a - b).max()) > 1e-6
                   for a, b in zip(la, lb))


class TestCheckpointResumeUnderFaults:
    """Satellite: the stale-upload cache rides in checkpoints, so a
    resumed run under faults reproduces masks, wallclock, AND the
    replayed free-rider uploads exactly."""

    @pytest.mark.parametrize("algorithm", ["proposed", "fedgan"])
    def test_fused_resume_under_faults_exact(self, tmp_path, algorithm):
        kw = dict(faults=FULL_FAULTS, algorithm=algorithm)
        ta = make_trainer("fused", **kw)
        ta.run(3)
        ta.save_checkpoint(str(tmp_path))
        tb = make_trainer("fused", **kw)
        assert tb.restore(str(tmp_path)) == 3
        tb.run(3)
        tc = make_trainer("fused", **kw)
        tc.run(6)
        assert "fault" in tb.state      # the cache survived the trip
        for a, b in zip(jax.tree_util.tree_leaves(tb.state),
                        jax.tree_util.tree_leaves(tc.state)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert tb._clock == tc._clock
        for rb, rc in zip(tb.history, tc.history[3:]):
            np.testing.assert_array_equal(rb.mask, rc.mask)
            assert rb.cumulative_s == rc.cumulative_s


class TestTrainerFaultValidation:
    def test_faults_device_count_mismatch_raises(self):
        with pytest.raises(ValueError, match="n_devices"):
            make_trainer("fused",
                         faults=FaultConfig(n_devices=K + 1))

    def test_centralized_rejects_faults(self):
        pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4)
        with pytest.raises(ValueError, match="centralized|faults"):
            Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), DATA,
                    KEY, algorithm="centralized", driver="host",
                    faults=FaultConfig(n_devices=K))

    def test_unknown_reducer_string_raises(self):
        with pytest.raises(ValueError):
            make_trainer("fused", reducer="median_of_means")

    def test_reducer_string_normalizes(self):
        t = make_trainer("fused", reducer="trimmed_mean")
        assert isinstance(t.reducer, RobustConfig)
        assert make_trainer("fused", reducer="mean").reducer is None


@pytest.mark.slow
@pytest.mark.robust
class TestMeshFaultEquivalence:
    """The 8-device matrix: host oracle vs stacked fused vs mesh fused
    under the full fault program, both algorithms, masks BITWISE and
    params to float32 tolerance — fault realizations are layout-
    independent (the byzantine one-flat-draw trick and the keyed
    dropout stream). Runs in CI's robust lane."""

    def test_fault_matrix_on_8_device_mesh(self):
        from conftest import run_on_host_mesh
        run_on_host_mesh("""
            import itertools
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs.base import ProtocolConfig
            from repro.configs.dcgan import DCGANConfig
            from repro.core import Trainer
            from repro.core.channel import ChannelConfig
            from repro.core.faults import FaultConfig
            from repro.kernels.robust_avg import RobustConfig
            from repro.models import dcgan
            from repro.models.specs import make_dcgan_spec

            KEY = jax.random.PRNGKey(0)
            CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
            SPEC = make_dcgan_spec(CFG)
            K = 8
            DATA = jax.random.normal(jax.random.PRNGKey(9),
                                     (K, 8, 8, 8, 1))
            FAULTS = FaultConfig(n_devices=K, dropout_prob=0.25,
                                 n_free_riders=2, n_byzantine=2,
                                 straggler_factor=2.0, seed=1)

            def make(driver, layout, algorithm, reducer=None):
                pcfg = ProtocolConfig(
                    n_devices=K, n_d=1, n_g=1, sample_size=4,
                    server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                    scheduler="round_robin", scheduling_ratio=0.5,
                    quantize_bits=16)
                chan = ChannelConfig(n_devices=K, seed=3, fading=False)
                return Trainer(SPEC, pcfg,
                               lambda k: dcgan.gan_init(k, CFG), DATA,
                               KEY, channel_cfg=chan, driver=driver,
                               layout=layout, algorithm=algorithm,
                               faults=FAULTS, reducer=reducer)

            def leaves(t):
                return jax.tree_util.tree_leaves(t.state)

            reducers = (None, RobustConfig(method="trimmed_mean", trim=1),
                        RobustConfig(method="norm_clip"),
                        RobustConfig(method="krum", krum_f=2))
            for algorithm, reducer in itertools.product(
                    ("proposed", "fedgan"), reducers):
                th = make("host", "stacked", algorithm, reducer)
                ts = make("fused", "stacked", algorithm, reducer)
                tm = make("fused", "mesh", algorithm, reducer)
                h, s, m = th.run(4), ts.run(4), tm.run(4)
                for rh, rs, rm in zip(h, s, m):
                    np.testing.assert_array_equal(rh.mask, rs.mask)
                    np.testing.assert_array_equal(rh.mask, rm.mask)
                    np.testing.assert_allclose(rh.wallclock_s,
                                               rm.wallclock_s, rtol=1e-5)
                for a, b in zip(leaves(th), leaves(tm)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=2e-5)
                for a, b in zip(leaves(ts), leaves(tm)):
                    np.testing.assert_allclose(
                        np.asarray(a, np.float32),
                        np.asarray(b, np.float32), atol=2e-5)
                name = reducer.method if reducer else "mean"
                print(f"fault matrix OK algorithm={algorithm} "
                      f"reducer={name}")

            # mesh resume under faults: stale cache + masks + wallclock
            import tempfile
            for algorithm in ("proposed", "fedgan"):
                d = tempfile.mkdtemp()
                ta = make("fused", "mesh", algorithm)
                ta.run(2)
                ta.save_checkpoint(d)
                tb = make("fused", "mesh", algorithm)
                tb.restore(d)
                tb.run(2)
                tc = make("fused", "mesh", algorithm)
                tc.run(4)
                for a, b in zip(leaves(tb), leaves(tc)):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
                for rb, rc in zip(tb.history, tc.history[2:]):
                    np.testing.assert_array_equal(rb.mask, rc.mask)
                    assert rb.cumulative_s == rc.cumulative_s
                print(f"mesh fault resume OK algorithm={algorithm}")
        """)

"""Flash (blockwise) attention vs the naive reference — forward and
gradients, across masks, dtypes, block sizes, and GQA folding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.nn.attention as attn_mod
from repro import nn
from repro.nn.flash_ref import flash_attention_ref

KEY = jax.random.PRNGKey(0)


def naive(q, k, v, q_pos, k_pos, scale, causal, window, k_valid=None):
    s = jnp.einsum("...qd,...kd->...qk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    from repro.nn.flash_ref import _block_bias
    s = s + _block_bias(q_pos, k_pos, causal, window, k_valid)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 7),
                                           (False, None)])
@pytest.mark.parametrize("block_k", [16, 64, 1000])
def test_flash_matches_naive(causal, window, block_k):
    b, h, sq, sk, d = 2, 3, 24, 40, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d))
    k = jax.random.normal(ks[1], (b, h, sk, d))
    v = jax.random.normal(ks[2], (b, h, sk, d))
    q_pos = jnp.broadcast_to(jnp.arange(sk - sq, sk), (b, 1, sq))
    k_pos = jnp.broadcast_to(jnp.arange(sk), (b, 1, sk))
    out = flash_attention_ref(q, k, v, q_pos, k_pos, None, d ** -0.5,
                              causal, window, block_k, False)
    ref = naive(q, k, v, q_pos, k_pos, d ** -0.5, causal, window)
    np.testing.assert_allclose(out, ref, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_grads_match(dtype):
    b, h, s, d = 1, 2, 33, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype=dtype)
    k = jax.random.normal(ks[1], (b, h, s, d), dtype=dtype)
    v = jax.random.normal(ks[2], (b, h, s, d), dtype=dtype)
    pos = jnp.broadcast_to(jnp.arange(s), (b, 1, s))

    def f_flash(q, k, v):
        return flash_attention_ref(q, k, v, pos, pos, None, d ** -0.5,
                                   True, None, 16, False).astype(
            jnp.float32).sum()

    def f_naive(q, k, v):
        return naive(q, k, v, pos, pos, d ** -0.5, True,
                     None).astype(jnp.float32).sum()

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 0.05
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32), atol=tol)


def test_attention_layer_flash_vs_naive_path():
    """attention_apply must agree with itself across the threshold."""
    p = nn.attention_init(KEY, 64, 8, 2)
    x = jax.random.normal(KEY, (2, 80, 64))
    inv = nn.rope_frequencies(8)
    old = attn_mod._FLASH_THRESHOLD
    try:
        attn_mod._FLASH_THRESHOLD = 1 << 62
        y_naive = nn.attention_apply(p, x, n_heads=8, n_kv_heads=2,
                                     inv_freq=inv, window=13)
        attn_mod._FLASH_THRESHOLD = 1
        y_flash = nn.attention_apply(p, x, n_heads=8, n_kv_heads=2,
                                     inv_freq=inv, window=13)
    finally:
        attn_mod._FLASH_THRESHOLD = old
    np.testing.assert_allclose(y_naive, y_flash, atol=3e-5)


def test_flash_kvalid_padding():
    """Invalid cache slots must not contribute."""
    b, h, s, d = 1, 1, 4, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, h, s, d))
    v = jax.random.normal(ks[2], (b, h, s, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, 1, s))
    valid = jnp.asarray([[[True, True, False, True]]])
    out = flash_attention_ref(q, k, v, pos, pos, valid, d ** -0.5,
                              False, None, 2, True)
    ref = naive(q, k, v, pos, pos, d ** -0.5, False, None, valid)
    np.testing.assert_allclose(out, ref, atol=1e-5)

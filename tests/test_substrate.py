"""Data pipeline, FID metric, checkpointing, optimizers."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import save_checkpoint, load_checkpoint, latest_step
from repro.data import make_image_dataset, make_token_dataset, partition
from repro.metrics import fid_score, make_feature_extractor
from repro.metrics.fid import frechet_distance, make_token_feature_extractor
from repro.optim import make_optimizer, apply_updates

KEY = jax.random.PRNGKey(0)


class TestData:
    def test_image_dataset_ranges(self):
        imgs, labels = make_image_dataset("toy", 64)
        assert imgs.shape == (64, 32, 32, 1)
        assert imgs.min() >= -1 and imgs.max() <= 1
        assert labels.shape == (64,)

    def test_partition_iid_shapes(self):
        imgs, _ = make_image_dataset("toy", 103)
        shards = partition(imgs, 10)
        assert shards.shape == (10, 10, 32, 32, 1)

    def test_partition_preserves_rows(self):
        data = np.arange(40).reshape(20, 2).astype(np.float32)
        shards = partition(data, 4)
        flat = sorted(map(tuple, shards.reshape(-1, 2).tolist()))
        assert flat == sorted(map(tuple, data.tolist()))

    def test_dirichlet_skew(self):
        data = np.arange(400).reshape(200, 2).astype(np.float32)
        labels = np.repeat(np.arange(4), 50)
        shards = partition(data, 4, labels=labels, kind="dirichlet",
                           alpha=0.1, seed=0)
        assert shards.shape[0] == 4 and shards.shape[1] > 0

    def test_token_dataset(self):
        toks, labels = make_token_dataset(8, 32, 100, n_modes=3)
        assert toks.shape == (8, 32)
        assert toks.min() >= 0 and toks.max() < 100


class TestFID:
    def test_identical_distributions_near_zero(self):
        f = jax.random.normal(KEY, (512, 16))
        assert fid_score(f, f) < 1e-6

    def test_mean_shift_increases(self):
        f = np.asarray(jax.random.normal(KEY, (512, 16)))
        d1 = fid_score(f, f + 0.5)
        d2 = fid_score(f, f + 2.0)
        assert 0 < d1 < d2

    def test_gaussian_closed_form(self):
        """1-D Gaussians: FID = (mu1-mu2)^2 + (s1-s2)^2."""
        d = frechet_distance(np.asarray([1.0]), np.asarray([[4.0]]),
                             np.asarray([3.0]), np.asarray([[9.0]]))
        assert d == pytest.approx((1 - 3) ** 2 + (2 - 3) ** 2, rel=1e-6)

    def test_feature_extractor_discriminates(self):
        feat = make_feature_extractor(1)
        a, _ = make_image_dataset("toy", 128, seed=0)
        b, _ = make_image_dataset("toy", 128, seed=0)
        noise = np.random.default_rng(0).uniform(-1, 1, a.shape).astype(
            np.float32)
        same = fid_score(feat(jnp.asarray(a)), feat(jnp.asarray(b)))
        diff = fid_score(feat(jnp.asarray(a)), feat(jnp.asarray(noise)))
        assert diff > 10 * max(same, 1e-9)

    def test_token_features(self):
        feat = make_token_feature_extractor(50)
        toks, _ = make_token_dataset(16, 24, 50)
        out = feat(jnp.asarray(toks))
        assert out.shape[0] == 16 and jnp.isfinite(out).all()


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {
            "gen": {"w": jnp.arange(6.0).reshape(2, 3),
                    "layers": [{"a": jnp.ones(2)}, {"a": jnp.zeros(2)}]},
            "count": jnp.int32(7),
            "maybe": None,
        }
        path = save_checkpoint(str(tmp_path), 3, tree,
                               metadata={"round": 3})
        assert os.path.exists(path)
        loaded, step, meta = load_checkpoint(str(tmp_path))
        assert step == 3 and meta["round"] == 3
        np.testing.assert_array_equal(loaded["gen"]["w"],
                                      np.arange(6.0).reshape(2, 3))
        assert isinstance(loaded["gen"]["layers"], list)
        np.testing.assert_array_equal(loaded["gen"]["layers"][0]["a"],
                                      np.ones(2))
        assert loaded["maybe"] is None
        assert int(loaded["count"]) == 7

    def test_latest_step(self, tmp_path):
        assert latest_step(str(tmp_path)) is None
        save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(1)})
        save_checkpoint(str(tmp_path), 5, {"x": jnp.ones(1)})
        assert latest_step(str(tmp_path)) == 5

    def test_bfloat16_roundtrips_bitwise(self, tmp_path):
        """bf16 leaves (the launch path's compute dtype) must come back
        bit-exact — np.savez stores ml_dtypes arrays as raw void bytes,
        so the checkpoint stores their uint16 view instead
        (launch/train.py --resume of a bf16 state hits this)."""
        x = (jnp.arange(7.0, dtype=jnp.float32) * 0.3).astype(jnp.bfloat16)
        tree = {"p": {"w": x}, "f32": jnp.ones(2)}
        save_checkpoint(str(tmp_path), 1, tree)
        loaded, _, _ = load_checkpoint(str(tmp_path))
        assert loaded["p"]["w"].dtype == np.asarray(x).dtype
        np.testing.assert_array_equal(
            np.asarray(loaded["p"]["w"]).view(np.uint16),
            np.asarray(x).view(np.uint16))
        back = jnp.asarray(loaded["p"]["w"], jnp.bfloat16)
        np.testing.assert_array_equal(np.asarray(back, np.float32),
                                      np.asarray(x, np.float32))


class TestOptim:
    def test_sgd_descends_quadratic(self):
        opt = make_optimizer("sgd", 0.1)
        x = {"v": jnp.asarray(4.0)}
        st = opt.init(x)
        for _ in range(50):
            g = jax.tree.map(lambda v: 2 * v, x)
            up, st = opt.update(g, st, x)
            x = apply_updates(x, up)
        assert abs(float(x["v"])) < 1e-3

    @pytest.mark.parametrize("name", ["momentum", "adam"])
    def test_stateful_optimizers_converge(self, name):
        opt = make_optimizer(name, 0.05)
        x = {"v": jnp.asarray(4.0)}
        st = opt.init(x)
        for _ in range(300):
            g = jax.tree.map(lambda v: 2 * v, x)
            up, st = opt.update(g, st, x)
            x = apply_updates(x, up)
        assert abs(float(x["v"])) < 1e-2

    def test_adam_bias_correction_first_step(self):
        """First Adam step ~= lr * sign(grad) regardless of magnitude."""
        opt = make_optimizer("adam", 0.01)
        x = {"v": jnp.asarray(1.0)}
        st = opt.init(x)
        up, _ = opt.update({"v": jnp.asarray(1e-4)}, st, x)
        assert float(up["v"]) == pytest.approx(-0.01, rel=1e-3)

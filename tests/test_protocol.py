"""Protocol mechanics: Algorithms 1-3, schedules, averaging, micro-batching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import protocol
from repro.core.averaging import weighted_average, broadcast_like
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)
CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=16)
SPEC = make_dcgan_spec(CFG)


def make_data(k_dev=4, n_k=8):
    return jax.random.normal(jax.random.PRNGKey(9),
                             (k_dev, n_k, 16, 16, 1))


def make_state(pcfg, k_dev=4):
    return protocol.make_train_state(
        KEY, lambda k: dcgan.gan_init(k, CFG), pcfg, k_dev)


def leaves_close(a, b, atol=1e-6):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


class TestAveraging:
    def test_equal_weights_is_mean(self):
        tree = {"a": jnp.arange(12.0).reshape(4, 3)}
        avg = weighted_average(tree, jnp.ones(4))
        np.testing.assert_allclose(avg["a"], tree["a"].mean(0))

    def test_weights_exclude(self):
        tree = {"a": jnp.stack([jnp.zeros(3), jnp.ones(3) * 7])}
        avg = weighted_average(tree, jnp.asarray([0.0, 5.0]))
        np.testing.assert_allclose(avg["a"], 7.0)

    def test_mk_weighting(self):
        """phi = sum m_k phi_k / sum m_k (Algorithm 2 exactly)."""
        phis = jnp.asarray([[1.0], [4.0], [10.0]])
        m = jnp.asarray([1.0, 2.0, 3.0])
        avg = weighted_average({"p": phis}, m)["p"]
        np.testing.assert_allclose(avg, (1 + 8 + 30) / 6.0)

    def test_broadcast_like(self):
        t = broadcast_like({"x": jnp.ones((2, 2))}, 5)
        assert t["x"].shape == (5, 2, 2)


class TestRound:
    def test_round_runs_and_moves_params(self):
        pcfg = ProtocolConfig(n_devices=4, n_d=2, n_g=2, sample_size=4,
                              server_sample_size=4, lr_d=1e-3, lr_g=1e-3)
        state = make_state(pcfg)
        data = make_data()
        w = jnp.full((4,), 4.0)
        new_state, metrics = protocol.gan_round(SPEC, pcfg, state, data, w,
                                                KEY)
        for leaf in jax.tree_util.tree_leaves(new_state):
            assert jnp.isfinite(leaf).all()
        # params actually moved
        d0 = jax.tree_util.tree_leaves(state["gen"])[0]
        d1 = jax.tree_util.tree_leaves(new_state["gen"])[0]
        assert float(jnp.abs(d0 - d1).max()) > 0
        assert metrics["participation"] == 1.0

    def test_zero_weight_device_excluded(self):
        """A device with weight 0 must not influence the global disc."""
        pcfg = ProtocolConfig(n_devices=2, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4)
        state = make_state(pcfg, 2)
        data = make_data(2)
        poisoned = jax.tree.map(lambda x: x, data)
        poisoned = poisoned.at[1].set(1e3)   # garbage on device 1
        w = jnp.asarray([4.0, 0.0])
        s1, _ = protocol.gan_round(SPEC, pcfg, state, data, w, KEY)
        s2, _ = protocol.gan_round(SPEC, pcfg, state, poisoned, w, KEY)
        leaves_close(s1["disc"], s2["disc"])

    def test_parallel_vs_serial_disc_identical_gen_differs(self):
        """Both schedules produce the same averaged discriminator; the
        generator differs because serial uses the fresh phi^{t+1}."""
        common = dict(n_devices=4, n_d=2, n_g=2, sample_size=4,
                      server_sample_size=4, lr_d=5e-3, lr_g=5e-3)
        p_ser = ProtocolConfig(schedule="serial", **common)
        p_par = ProtocolConfig(schedule="parallel", **common)
        state = make_state(p_ser)
        data = make_data()
        w = jnp.full((4,), 4.0)
        s_ser, _ = protocol.gan_round(SPEC, p_ser, state, data, w, KEY)
        s_par, _ = protocol.gan_round(SPEC, p_par, state, data, w, KEY)
        leaves_close(s_ser["disc"], s_par["disc"])
        g1 = jax.tree_util.tree_leaves(s_ser["gen"])
        g2 = jax.tree_util.tree_leaves(s_par["gen"])
        assert any(float(jnp.abs(a - b).max()) > 1e-7 for a, b in zip(g1, g2))

    def test_parallel_gen_update_ignores_device_updates(self):
        """Parallel schedule: generator update depends only on phi^t, so
        corrupting the device data must not change the new generator."""
        pcfg = ProtocolConfig(schedule="parallel", n_devices=2, n_d=3,
                              n_g=2, sample_size=4, server_sample_size=4)
        state = make_state(pcfg, 2)
        data = make_data(2)
        w = jnp.full((2,), 4.0)
        s1, _ = protocol.gan_round(SPEC, pcfg, state, data, w, KEY)
        s2, _ = protocol.gan_round(SPEC, pcfg, state, data * -3.0, w, KEY)
        leaves_close(s1["gen"], s2["gen"])

    def test_centralized_equals_k1_round(self):
        # quantize_bits=32: centralized training has no uplink, so the
        # K=1 round must run with the float32-identity uplink to match.
        pcfg = ProtocolConfig(n_devices=1, n_d=2, n_g=2, sample_size=4,
                              server_sample_size=4, quantize_bits=32)
        state = make_state(pcfg, 1)
        data = make_data(1)
        s_round, _ = protocol.gan_round(SPEC, pcfg, state, data,
                                        jnp.asarray([4.0]), KEY)
        s_cent, _ = protocol.centralized_step(SPEC, pcfg, state, data[0], KEY)
        leaves_close(s_round["gen"], s_cent["gen"])
        leaves_close(s_round["disc"], s_cent["disc"])

    def test_microbatch_invariance(self):
        """Gradient accumulation must not change the result (SGD linear)."""
        common = dict(n_devices=2, n_d=1, n_g=1, sample_size=8,
                      server_sample_size=8)
        p_full = ProtocolConfig(**common)
        p_micro = ProtocolConfig(micro_batch_d=2, micro_batch_g=4, **common)
        state = make_state(p_full, 2)
        data = make_data(2)
        w = jnp.full((2,), 8.0)
        s1, _ = protocol.gan_round(SPEC, p_full, state, data, w, KEY)
        s2, _ = protocol.gan_round(SPEC, p_micro, state, data, w, KEY)
        # DCGAN BatchNorm normalizes per microbatch, so equality is only
        # approximate here; BN-free backbones accumulate exactly.
        leaves_close(s1["gen"], s2["gen"], atol=5e-4)
        leaves_close(s1["disc"], s2["disc"], atol=5e-4)

    def test_shared_seed_consistency(self):
        """Parallel schedule seed contract: the server's noise at step j
        equals every device's noise at step j (Section III-A)."""
        from repro.core.protocol import _SALT_SHARED_Z
        kz_server = jax.random.fold_in(jax.random.fold_in(KEY, _SALT_SHARED_Z), 0)
        kz_device = jax.random.fold_in(jax.random.fold_in(KEY, _SALT_SHARED_Z), 0)
        np.testing.assert_array_equal(
            jax.random.key_data(kz_server), jax.random.key_data(kz_device))


class TestOptimizers:
    def test_adam_state_threads_through_round(self):
        pcfg = ProtocolConfig(n_devices=2, n_d=1, n_g=1, sample_size=4,
                              server_sample_size=4, optimizer="adam")
        state = make_state(pcfg, 2)
        data = make_data(2)
        w = jnp.full((2,), 4.0)
        s1, _ = protocol.gan_round(SPEC, pcfg, state, data, w, KEY)
        assert int(s1["gen_opt"]["t"]) == 1
        assert np.asarray(s1["disc_opt"]["t"]).tolist() == [1, 1]
        s2, _ = protocol.gan_round(SPEC, pcfg, s1, data, w,
                                   jax.random.fold_in(KEY, 1))
        assert int(s2["gen_opt"]["t"]) == 2

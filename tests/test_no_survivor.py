"""No-survivor rounds: when every worker drops, the globals FREEZE.

The bug this pins (PR 9 satellite): a round where the total aggregation
weight is zero has no defined average — `_normalized`'s
`max(total, 1e-12)` guard silently multiplied the previous global by ~0
instead of keeping it. Every averaging impl (host stacked jnp/pallas,
robust reducers, mesh psum jnp/pallas, ring) now takes `fallback` and
returns it unchanged when the total weight is zero, and both round
bodies (protocol.gan_round, fedgan.fedgan_round) pass the round-start
globals, so `FaultConfig(dropout_prob=1.0)` — now legal — freezes the
trajectory identically on the host oracle and the fused scan.

The mesh-layout twin of the Trainer regression runs inside the
8-device subprocess matrix in test_driver_equivalence.py's mesh lane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.core.averaging import weighted_average, weighted_average_psum
from repro.core.channel import ChannelConfig
from repro.core.faults import FaultConfig
from repro.kernels.robust_avg import RobustConfig
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

KEY = jax.random.PRNGKey(0)
CFG = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
SPEC = make_dcgan_spec(CFG)
K = 4
DATA = jax.random.normal(jax.random.PRNGKey(9), (K, 8, 8, 8, 1))
AXIS = "k"


def make_case(seed=0, k=K):
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.standard_normal((k, 37)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((k, 5, 3)), jnp.float32)}
    fallback = {"a": jnp.asarray(rng.standard_normal(37), jnp.float32),
                "b": jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)}
    return tree, jnp.zeros(k, jnp.float32), fallback


def assert_is_fallback(out, fallback):
    for a, f in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(fallback)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(f))


class TestFaultConfigValidation:
    def test_dropout_prob_one_is_legal(self):
        cfg = FaultConfig(n_devices=K, dropout_prob=1.0)
        assert cfg.dropout_prob == 1.0

    @pytest.mark.parametrize("p", [-0.1, 1.1])
    def test_dropout_prob_out_of_range_raises(self, p):
        with pytest.raises(ValueError, match="dropout_prob"):
            FaultConfig(n_devices=K, dropout_prob=p)


class TestStackedFallback:
    """weighted_average (host/stacked path) across impls."""

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_zero_weights_return_fallback(self, impl):
        tree, w, fb = make_case()
        out = weighted_average(tree, w, impl=impl, fallback=fb)
        assert_is_fallback(out, fb)

    @pytest.mark.parametrize("method", ["trimmed_mean", "norm_clip",
                                        "krum"])
    def test_robust_zero_weights_return_fallback(self, method):
        tree, w, fb = make_case()
        out = weighted_average(tree, w, robust=RobustConfig(method=method),
                               fallback=fb)
        assert_is_fallback(out, fb)

    def test_nonzero_weights_ignore_fallback(self):
        tree, _, fb = make_case()
        w = jnp.asarray([1.0, 2.0, 0.0, 3.0], jnp.float32)
        with_fb = weighted_average(tree, w, fallback=fb)
        without = weighted_average(tree, w)
        assert_is_fallback(with_fb, without)


class TestPsumFallback:
    """weighted_average_psum (mesh path) across impls, collectives under
    vmap(axis_name=...) — the test_averaging_property.py harness."""

    @pytest.mark.parametrize("impl", ["jnp", "pallas", "ring"])
    def test_zero_weights_return_fallback(self, impl):
        tree, w, fb = make_case()
        out = jax.vmap(
            lambda t, wi: weighted_average_psum(
                t, wi, axis_names=AXIS, impl=impl, fallback=fb),
            axis_name=AXIS)(tree, w)
        assert_is_fallback(jax.tree.map(lambda x: x[0], out), fb)

    def test_robust_zero_weights_return_fallback(self):
        tree, w, fb = make_case()
        out = jax.vmap(
            lambda t, wi: weighted_average_psum(
                t, wi, axis_names=AXIS,
                robust=RobustConfig(method="trimmed_mean"), fallback=fb),
            axis_name=AXIS)(tree, w)
        assert_is_fallback(jax.tree.map(lambda x: x[0], out), fb)


def make_trainer(driver, *, algorithm="proposed", reducer=None):
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3,
                          quantize_bits=16)
    chan = ChannelConfig(n_devices=K, seed=3, fading=False)
    faults = FaultConfig(n_devices=K, dropout_prob=1.0)
    return Trainer(SPEC, pcfg, lambda k: dcgan.gan_init(k, CFG), DATA, KEY,
                   channel_cfg=chan, driver=driver, algorithm=algorithm,
                   faults=faults, reducer=reducer)


class TestTrainerAllDropped:
    """The end-to-end regression: FaultConfig(dropout_prob=1.0) freezes
    the worker-averaged globals EXACTLY, in both drivers."""

    @pytest.mark.parametrize("driver", ["host", "fused"])
    def test_proposed_disc_frozen(self, driver):
        tr = make_trainer(driver)
        disc0 = jax.tree.map(np.asarray, tr.state["disc"])
        hist = tr.run(4)
        assert_is_fallback(tr.state["disc"], disc0)
        assert all(r.metrics["participation"] == 0.0 for r in hist)
        assert all(not r.mask.any() for r in hist)

    @pytest.mark.parametrize("driver", ["host", "fused"])
    def test_fedgan_gen_and_disc_frozen(self, driver):
        tr = make_trainer(driver, algorithm="fedgan")
        gen0 = jax.tree.map(np.asarray, tr.state["gen"])
        disc0 = jax.tree.map(np.asarray, tr.state["disc"])
        tr.run(4)
        assert_is_fallback(tr.state["gen"], gen0)
        assert_is_fallback(tr.state["disc"], disc0)

    def test_drivers_agree(self):
        th, tf = make_trainer("host"), make_trainer("fused")
        h, f = th.run(3), tf.run(3)
        for a, b in zip(jax.tree_util.tree_leaves(th.state["disc"]),
                        jax.tree_util.tree_leaves(tf.state["disc"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-5)
        for rh, rf in zip(h, f):
            np.testing.assert_array_equal(rh.mask, rf.mask)

    def test_robust_reducer_disc_frozen(self):
        tr = make_trainer("fused", reducer="trimmed_mean")
        disc0 = jax.tree.map(np.asarray, tr.state["disc"])
        tr.run(3)
        assert_is_fallback(tr.state["disc"], disc0)

"""Fig. 4 — device-count scaling vs centralized training (serial
schedule, CelebA). Paper claim: with the same per-iteration data budget,
K-device training converges to the same FID as centralized, slightly
faster."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import run_experiment, last_fid, emit_csv_row


def main(out_dir="results/bench"):
    os.makedirs(out_dir, exist_ok=True)
    curves = []
    settings = [("centralized", "centralized", 10),
                ("K=5", "proposed", 5),
                ("K=10", "proposed", 10)]
    for label, algorithm, k in settings:
        t0 = time.time()
        c = run_experiment(f"fig4/{label}", dataset="celeba",
                           algorithm=algorithm, k=k)
        dt = (time.time() - t0) * 1e6 / max(len(c.rounds), 1)
        curves.append(c)
        emit_csv_row(f"fig4_{label}", dt, f"final_fid={last_fid(c):.2f}")
    with open(os.path.join(out_dir, "fig4_devices.json"), "w") as f:
        json.dump([c.as_dict() for c in curves], f, indent=2)
    return curves


if __name__ == "__main__":
    main()

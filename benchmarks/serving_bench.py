"""Serving engine benchmark: paged-cache memory vs the dense baseline,
and a closed-loop load sweep (p50/p99 latency + tokens/sec vs offered
QPS) through the async front-end.

Two measurements, both merged into BENCH_serving.json:

  cache — the SAME greedy workload runs through a dense engine
      (per-slot max_len reservation) and a paged engine whose block pool
      is sized to the workload's live tokens. Outputs must be identical
      (sampling is keyed by (seed, rid, token_index), so tokens are
      scheduling- and backend-independent); the paged engine's
      persistent cache bytes per request must be STRICTLY below the
      dense baseline — that is the point of paging, and `--smoke` exits
      non-zero if it regresses.

  load — a closed-loop generator submits Poisson arrivals at each
      offered QPS through `ServingFrontend`, awaiting every request's
      Future for end-to-end latency. Recorded per QPS point: completed
      requests, p50/p99 latency (ms), decoded tokens/sec, wall time.

The bench model is a reduced config (default qwen3-1.7b — full
attention, where paging matters most; REPRO_SERVING_BENCH_ARCH
overrides). Sizes shrink under --smoke so the CI lane finishes in
seconds while still exercising admission, chunked prefill, any-position
decode, retirement, and the paged pool.

    PYTHONPATH=src python benchmarks/serving_bench.py           # full
    PYTHONPATH=src python benchmarks/serving_bench.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_arch_config
from repro.models import gan
from repro.serving import ServingEngine, ServingFrontend, Request
from repro.serving import cache as paging

ARCH = os.environ.get("REPRO_SERVING_BENCH_ARCH", "qwen3-1.7b")
SEED = 0


def make_workload(cfg, n_requests: int, rng):
    """(prompt, max_new) pairs with mixed lengths."""
    return [(rng.integers(1, cfg.vocab, rng.integers(4, 24)).astype(np.int32),
             int(rng.integers(4, 12)))
            for _ in range(n_requests)]


def run_engine(cfg, params, workload, *, batch_size, max_len, block_size,
               n_blocks=None, prefill_chunk=16):
    eng = ServingEngine(cfg, params, batch_size=batch_size, max_len=max_len,
                        block_size=block_size, n_blocks=n_blocks,
                        prefill_chunk=prefill_chunk, seed=SEED)
    for i, (prompt, max_new) in enumerate(workload):
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    finished = eng.run()
    assert len(finished) == len(workload), (
        f"{len(finished)}/{len(workload)} finished; "
        f"rejected: {[r.failed for r in eng.rejected]}")
    outputs = {r.rid: list(r.out_tokens) for r in finished}
    return eng, outputs


def bench_cache(cfg, params, *, batch_size, max_len, block_size,
                n_requests):
    """Dense vs right-sized paged pool on an identical greedy workload."""
    rng = np.random.default_rng(0)
    workload = make_workload(cfg, n_requests, rng)

    dense_eng, dense_out = run_engine(
        cfg, params, workload, batch_size=batch_size, max_len=max_len,
        block_size=None)
    # pool sized to the workload: enough blocks for a full batch of the
    # LARGEST live request footprint (prompt + generated), not max_len
    live = max(len(p) + m for p, m in workload)
    n_blocks = batch_size * paging.slot_max_blocks(live, block_size) + 1
    paged_eng, paged_out = run_engine(
        cfg, params, workload, batch_size=batch_size, max_len=max_len,
        block_size=block_size, n_blocks=n_blocks)

    equal = dense_out == paged_out
    dense_bytes = dense_eng.cache_bytes()
    paged_bytes = paged_eng.cache_bytes()
    return {
        "requests": n_requests,
        "max_live_tokens_per_request": live,
        "dense_bytes": dense_bytes,
        "paged_bytes": paged_bytes,
        "dense_bytes_per_request": dense_bytes // batch_size,
        "paged_bytes_per_request": paged_bytes // batch_size,
        "paged_over_dense": round(paged_bytes / dense_bytes, 4),
        "equal_outputs": bool(equal),
        "paged_compile_count": paged_eng.compile_count,
    }


def bench_load(cfg, params, *, batch_size, max_len, block_size,
               qps_points, n_requests):
    """Closed-loop Poisson load through the async front-end."""
    results = []
    rng = np.random.default_rng(1)
    for qps in qps_points:
        eng = ServingEngine(cfg, params, batch_size=batch_size,
                            max_len=max_len, block_size=block_size,
                            prefill_chunk=16, seed=SEED)
        workload = make_workload(cfg, n_requests, rng)
        lat = {}
        futures = []
        with ServingFrontend(eng) as fe:
            t_start = time.perf_counter()
            for prompt, max_new in workload:
                fut = fe.submit(prompt, max_new_tokens=max_new)
                t_sub = time.perf_counter()
                fut.add_done_callback(
                    lambda f, t=t_sub: lat.__setitem__(
                        id(f), time.perf_counter() - t))
                futures.append(fut)
                time.sleep(rng.exponential(1.0 / qps))
            reqs = [f.result(timeout=300) for f in futures]
            wall = time.perf_counter() - t_start
        lats_ms = sorted(1e3 * lat[id(f)] for f in futures)
        n_tok = sum(len(r.out_tokens) for r in reqs)
        results.append({
            "offered_qps": qps,
            "completed": len(reqs),
            "p50_ms": round(lats_ms[len(lats_ms) // 2], 2),
            "p99_ms": round(lats_ms[min(len(lats_ms) - 1,
                                        int(len(lats_ms) * 0.99))], 2),
            "tokens_per_sec": round(n_tok / wall, 2),
            "wall_s": round(wall, 2),
        })
        print(f"  qps={qps}: p50={results[-1]['p50_ms']}ms "
              f"p99={results[-1]['p99_ms']}ms "
              f"tok/s={results[-1]['tokens_per_sec']}")
    return results


def write_json(path, entry):
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload[ARCH] = entry
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI run; exit non-zero if paged memory "
                         "per request is not strictly below dense, or "
                         "outputs diverge")
    ap.add_argument("--batch-size", type=int, default=None)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--json", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    batch = args.batch_size or (2 if args.smoke else 4)
    max_len = args.max_len or (128 if args.smoke else 256)
    n_req = 6 if args.smoke else 24
    qps_points = [2.0, 8.0] if args.smoke else [1.0, 4.0, 16.0, 64.0]

    cfg = get_arch_config(ARCH).reduced()
    params = gan.generator_init(jax.random.PRNGKey(0), cfg)

    print(f"serving bench: {ARCH} (reduced), batch={batch}, "
          f"max_len={max_len}, block={args.block_size}")
    cache = bench_cache(cfg, params, batch_size=batch, max_len=max_len,
                        block_size=args.block_size, n_requests=n_req)
    print(f"  cache/request: dense {cache['dense_bytes_per_request']} B, "
          f"paged {cache['paged_bytes_per_request']} B "
          f"({cache['paged_over_dense']:.2f}x), "
          f"equal_outputs={cache['equal_outputs']}")
    load = bench_load(cfg, params, batch_size=batch, max_len=max_len,
                      block_size=args.block_size, qps_points=qps_points,
                      n_requests=n_req)

    entry = {"engine": {"batch_size": batch, "max_len": max_len,
                        "block_size": args.block_size,
                        "prefill_chunk": 16},
             "cache": cache, "load": load}
    write_json(args.json, entry)

    status = 0
    if not cache["equal_outputs"]:
        print("FAIL: paged outputs diverge from dense", file=sys.stderr)
        status = 2
    if cache["paged_bytes_per_request"] >= cache["dense_bytes_per_request"]:
        print("FAIL: paged cache bytes/request not below dense baseline",
              file=sys.stderr)
        status = 2
    if any(pt["completed"] != n_req for pt in load):
        print("FAIL: load sweep dropped requests", file=sys.stderr)
        status = 2
    return status


if __name__ == "__main__":
    sys.exit(main())

"""Generate EXPERIMENTS.md sections from results/ artifacts.

  §Dry-run      from results/dryrun/*.json (memory / collective schedule)
  §Roofline     three-term table + dominant bottleneck + useful ratio
  §Paper-validation  from results/bench/*.json curves
  §Perf         from results/perf/*.json hillclimb records
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline_report import load_rows  # noqa: E402


def fmt_dryrun_section():
    rows = load_rows()
    out = ["## §Dry-run\n"]
    out.append("Every (architecture × input shape) lowered AND compiled on "
               "the single-pod 16×16 mesh and the 2×16×16 multi-pod mesh "
               "(512 host placeholder devices). Per-device memory and the "
               "collective schedule come from `compiled.memory_analysis()` "
               "and the loop-aware HLO parse (`repro.launch.hlo_costs`).\n")
    out.append("NOTE: the CPU backend upcasts bf16 buffers to f32, so "
               "peak-GB figures are ≈2× the real TPU bf16 footprint; "
               "relative comparisons are unaffected.\n")
    out.append("| arch | shape | mesh | peak GB/dev | collectives "
               "(AG/AR/RS/A2A/CP) |")
    out.append("|---|---|---|---|---|")
    for p in sorted(glob.glob("results/dryrun/*.json")):
        if os.path.basename(p).count("__") != 2:
            continue
        d = json.load(open(p))
        counts = d["collectives"]["counts"]
        cstr = "/".join(str(counts.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        peak = (d["memory"].get("peak_bytes") or 0) / 1e9
        out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                   f"{peak:.2f} | {cstr} |")
    return "\n".join(out)


def fmt_roofline_section():
    rows = load_rows()
    out = ["## §Roofline\n"]
    out.append("Terms per the spec: compute = FLOPs/(chips·197 TF/s), "
               "memory = bytes/(chips·819 GB/s), collective = "
               "coll_bytes/(chips·50 GB/s). FLOPs/bytes are loop-aware "
               "HLO counts (XLA's cost_analysis counts while bodies once "
               "— see hlo_costs.py); MODEL_FLOPS = 6·N_active·D (train) "
               "or 2·N_active·D (serve); useful = MODEL_FLOPS/HLO_FLOPs.\n")
    out.append("| arch | shape | mesh | compute_s | memory_s | "
               "collective_s | dominant | useful | peak GB |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['peak_gb']:.2f} |")
    return "\n".join(out)


def fmt_bench_section():
    out = ["## §Paper-validation\n"]
    files = {
        "fig3_schedules": "Fig. 3 — serial vs parallel schedule, 3 datasets",
        "fig4_devices": "Fig. 4 — device count vs centralized",
        # fig5 writes one curves file per execution layout
        "fig5_fedgan_stacked": "Fig. 5 — proposed vs FedGAN (stacked)",
        "fig5_fedgan_mesh": "Fig. 5 — proposed vs FedGAN (mesh)",
        "fig6_scheduling": "Fig. 6 — scheduling ratio under stragglers",
    }
    for stem, title in files.items():
        path = f"results/bench/{stem}.json"
        if not os.path.exists(path):
            continue
        curves = json.load(open(path))
        out.append(f"### {title}\n")
        out.append("| setting | final FID | wall-clock (s) |")
        out.append("|---|---|---|")
        for c in curves:
            fids = [f for f in c["fid"] if f is not None]
            fid = fids[-1] if fids else float("nan")
            wall = c["wallclock"][-1] if c["wallclock"] else 0.0
            out.append(f"| {c['label']} | {fid:.2f} | {wall:.1f} |")
        out.append("")
    return "\n".join(out)


def fmt_perf_section():
    out = ["## §Perf\n"]
    files = sorted(glob.glob("results/perf/*.json"))
    if not files:
        out.append("(hillclimb records pending)")
    for p in files:
        d = json.load(open(p))
        out.append(f"### {d['pair']}\n")
        for it in d["iterations"]:
            out.append(f"- **{it['name']}** — hypothesis: {it['hypothesis']}")
            out.append(f"  - change: {it['change']}")
            out.append(f"  - before: {it['before']}  after: {it['after']}")
            out.append(f"  - verdict: {it['verdict']}")
        out.append("")
    return "\n".join(out)


def main():
    print(fmt_dryrun_section())
    print()
    print(fmt_roofline_section())
    print()
    print(fmt_bench_section())
    print()
    print(fmt_perf_section())


if __name__ == "__main__":
    main()

"""Roofline table from the dry-run JSONs (results/dryrun/*.json).

Per (arch x shape x mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS = 6*N_active*D, and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_arch_config, INPUT_SHAPES  # noqa: E402


def active_params(cfg) -> int:
    """Active (per-token) parameter count, MoE uses top_k experts."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    attn = d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd \
        + cfg.n_heads * hd * d
    if cfg.moe:
        ff = 3 * d * cfg.moe.d_ff_expert * cfg.moe.top_k + d * cfg.moe.n_experts
    elif cfg.family == "ssm":
        ff = 0
    else:
        ff = 3 * d * cfg.d_ff
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * d
        per = d * (2 * d_in + 2 * s.n_groups * s.d_state
                   + d_in // s.head_dim) + d_in * d
        return cfg.n_layers * per + 2 * cfg.vocab * d
    if cfg.family == "hybrid":
        s = cfg.ssm
        d_in = s.expand * d
        per = d * (2 * d_in + 2 * s.n_groups * s.d_state
                   + d_in // s.head_dim) + d_in * d
        shared = attn + 3 * d * cfg.d_ff
        return (cfg.n_layers * per
                + (cfg.n_layers // cfg.attn_every) * 0 + shared
                + 2 * cfg.vocab * d)
    per_layer = attn + ff
    n_cross = 0
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // (cfg.cross_attn_every + 1)
    return cfg.n_layers * per_layer + 2 * cfg.vocab * d


def tokens_processed(cfg, shape, pcfg_nd=5, pcfg_ng=5, k_dev=16) -> float:
    """Token-steps consumed by one step of this shape's kind."""
    if shape.kind == "train":
        n_k = shape.global_batch // k_dev
        disc_tokens = k_dev * pcfg_nd * n_k * shape.seq_len * 2  # real+fake
        gen_fwd_for_fakes = k_dev * pcfg_nd * n_k * shape.seq_len
        gen_tokens = pcfg_ng * k_dev * shape.seq_len
        return disc_tokens + gen_fwd_for_fakes + gen_tokens
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence


def load_rows(dry_dir="results/dryrun", tag=""):
    rows = []
    suffix = f"_{tag}.json" if tag else ".json"
    for path in sorted(glob.glob(os.path.join(dry_dir, f"*{suffix}"))):
        base = os.path.basename(path)
        if tag == "" and base.count("__") != 2:
            continue
        with open(path) as f:
            d = json.load(f)
        cfg = get_arch_config(d["arch"])
        shape = INPUT_SHAPES[d["shape"]]
        n_active = active_params(cfg)
        model_flops = 6.0 * n_active * tokens_processed(cfg, shape)
        if shape.kind != "train":
            model_flops = 2.0 * n_active * tokens_processed(cfg, shape)
        r = d["roofline"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "model_flops": model_flops,
            "useful_ratio": model_flops / r["flops"] if r["flops"] else 0.0,
            "peak_gb": (d["memory"].get("peak_bytes") or 0) / 1e9,
        })
    return rows


def main():
    rows = load_rows()
    if not rows:
        print("no dry-run results found — run repro.launch.dryrun first")
        return
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':6s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'peak_GB':>8s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:6s} "
              f"{r['compute_s']:10.3e} {r['memory_s']:10.3e} "
              f"{r['collective_s']:10.3e} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {r['peak_gb']:8.2f}")


if __name__ == "__main__":
    main()

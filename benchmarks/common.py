"""Shared experiment harness for the paper-figure benchmarks.

Each figure benchmark builds a fleet (DCGAN + synthetic dataset matched
to the paper's three datasets), runs communication rounds through the
Trainer (scheduling + channel timing + FID), and returns convergence
curves (round, wallclock_s, fid).

Scale: the container is a single CPU core, so the default is a reduced
DCGAN (32x32, ngf=ndf=16) and REPRO_BENCH_ROUNDS rounds (default 12).
The paper-faithful full-scale settings (64x64 DCGAN 3.58M/2.77M params,
n_d=n_g=5, m_k=128, K=10) are selected with REPRO_BENCH_FULL=1.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.core.engine import FUSED_ALGORITHMS
from repro.core.channel import ChannelConfig
from repro.data import make_image_dataset, partition, DATASET_SPECS
from repro.metrics import (feature_stats_jnp, frechet_distance_jnp,
                           make_feature_extractor)
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"
ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "60" if FULL else "12"))
EVAL_EVERY = int(os.environ.get("REPRO_BENCH_EVAL_EVERY", "4"))
# "fused" = compiled multi-round driver (the whole run is one donated
# chunk; FID runs in-scan); "host" = the per-round oracle loop; "auto"
# = fused where the algorithm supports it (proposed, fedgan).
DRIVER = os.environ.get("REPRO_BENCH_DRIVER", "auto")


def dataset_for(name: str):
    """Map the paper's dataset names onto synthetic stand-ins."""
    if FULL:
        return {"celeba": "celeba", "cifar10": "cifar10",
                "rsna": "rsna"}[name]
    return {"celeba": "celeba32", "cifar10": "cifar10",
            "rsna": "rsna32"}[name]


def dcgan_for(dataset: str) -> DCGANConfig:
    spec = DATASET_SPECS[dataset]
    if FULL:
        return DCGANConfig(nz=100, ngf=64, ndf=64, nc=spec.channels,
                           image_size=spec.image_size)
    return DCGANConfig(nz=32, ngf=16, ndf=16, nc=spec.channels,
                       image_size=spec.image_size)


def protocol_for(*, schedule="serial", k=10, scheduler="all", ratio=1.0,
                 optimizer="adam", bits=16) -> ProtocolConfig:
    # paper: n_d = n_g = 5, m_k = 128, 16-bit uplink; reduced keeps the
    # ratio structure
    return ProtocolConfig(
        n_devices=k,
        n_d=5 if FULL else 2,
        n_g=5 if FULL else 2,
        sample_size=128 if FULL else 16,
        server_sample_size=128 if FULL else 16,
        lr_d=2e-4 if optimizer == "adam" else 2e-3,
        lr_g=2e-4 if optimizer == "adam" else 2e-3,
        schedule=schedule,
        scheduler=scheduler,
        scheduling_ratio=ratio,
        quantize_bits=bits,
        optimizer=optimizer,
    )


@dataclasses.dataclass
class Curve:
    label: str
    rounds: list
    wallclock: list
    fid: list

    def as_dict(self):
        return dataclasses.asdict(self)


def run_experiment(label: str, *, dataset="celeba", algorithm="proposed",
                   schedule="serial", k=10, scheduler="all", ratio=1.0,
                   rounds=None, seed=0, channel_kw=None,
                   gen_loss="nonsaturating", driver=None,
                   bits=16, layout="stacked", faults=None,
                   reducer=None) -> Curve:
    ds = dataset_for(dataset)
    cfg = dcgan_for(ds)
    spec = make_dcgan_spec(cfg, gen_loss_variant=gen_loss)
    pcfg = protocol_for(schedule=schedule, k=k, scheduler=scheduler,
                        ratio=ratio, bits=bits)
    n = 1280 if FULL else 320
    imgs, labels = make_image_dataset(ds, n, seed=seed)
    shards = jnp.asarray(partition(imgs, k, seed=seed))

    feat = make_feature_extractor(cfg.nc)
    real_feats = feat(jnp.asarray(imgs[: min(n, 512)]))
    # pure-jnp FID against precomputed real stats: jittable, so fused
    # runs evaluate IN-SCAN (one compiled chunk, state stays donated)
    real_mu, real_cov = feature_stats_jnp(real_feats)

    def fid_fn(gen_params, key):
        z = jax.random.normal(key, (256, cfg.nz))
        fake = dcgan.generator_apply(gen_params, cfg, z)
        mu, cov = feature_stats_jnp(feat(fake))
        return frechet_distance_jnp(real_mu, real_cov, mu, cov)

    # FLOP estimates for the channel-time model (fwd+bwd ~ 3x fwd; DCGAN
    # fwd ~ 2 * params * pixels_factor — a coarse constant is fine, the
    # figures compare RELATIVE times)
    step_flops = 6.0 * 3.5e6 * (64 if FULL else 16)

    chan = ChannelConfig(n_devices=k, seed=seed,
                         **(channel_kw or {}))
    resolved_driver = driver or DRIVER
    if resolved_driver == "fused" and algorithm not in FUSED_ALGORITHMS:
        # REPRO_BENCH_DRIVER=fused applies to every figure's settings;
        # algorithms without a fused path (centralized) keep the host
        # loop instead of aborting the sweep.
        resolved_driver = "host"
    trainer = Trainer(spec, pcfg, lambda kk: dcgan.gan_init(kk, cfg),
                      shards, jax.random.PRNGKey(seed),
                      algorithm=algorithm, channel_cfg=chan,
                      disc_step_flops=step_flops,
                      gen_step_flops=step_flops,
                      driver=resolved_driver, layout=layout,
                      faults=faults, reducer=reducer)
    hist = trainer.run(rounds or ROUNDS, eval_every=EVAL_EVERY,
                       fid_fn=fid_fn)
    return Curve(
        label=label,
        rounds=[r.round for r in hist],
        wallclock=[r.cumulative_s for r in hist],
        fid=[r.fid for r in hist],
    )


def last_fid(curve: Curve):
    vals = [f for f in curve.fid if f is not None]
    return vals[-1] if vals else float("nan")


def emit_csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")

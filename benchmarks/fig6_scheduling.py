"""Fig. 6 — scheduling-ratio trade-off under heterogeneous channels with
variable upload times. Paper claim: scheduling 100% of devices performs
WORST (stragglers dominate the round time); 50% / 20% best-channel
scheduling wins in wall-clock."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import run_experiment, last_fid, emit_csv_row

# a tight per-round deadline makes bad-channel devices stragglers
CHANNEL = dict(fading=True, straggler_deadline_s=60.0)


def main(out_dir="results/bench", driver=None):
    # driver=None falls through to run_experiment's REPRO_BENCH_DRIVER default
    os.makedirs(out_dir, exist_ok=True)
    curves = []
    for ratio in (1.0, 0.5, 0.2):
        t0 = time.time()
        c = run_experiment(f"fig6/ratio={ratio}", dataset="celeba",
                           scheduler="best_channel", ratio=ratio,
                           channel_kw=CHANNEL, driver=driver)
        dt = (time.time() - t0) * 1e6 / max(len(c.rounds), 1)
        curves.append(c)
        emit_csv_row(f"fig6_ratio{int(ratio * 100)}", dt,
                     f"final_fid={last_fid(c):.2f};"
                     f"wallclock={c.wallclock[-1]:.1f}s")
    with open(os.path.join(out_dir, "fig6_scheduling.json"), "w") as f:
        json.dump([c.as_dict() for c in curves], f, indent=2)
    return curves


if __name__ == "__main__":
    main()

"""Benchmark driver: one benchmark per paper figure + kernel microbench
+ the roofline table from the dry-run. Prints ``name,us_per_call,derived``
CSV rows.

Scale via env: REPRO_BENCH_ROUNDS (default 12), REPRO_BENCH_FULL=1 for
the paper-faithful 64x64 DCGAN / n_d=n_g=5 / m_k=128 settings.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import kernels_bench
    kernels_bench.main()

    from benchmarks import driver_bench
    driver_bench.main()

    from benchmarks import fig3_schedules, fig4_devices, fig5_fedgan, \
        fig6_scheduling
    fig3_schedules.main()
    fig4_devices.main()
    fig5_fedgan.main()
    fig6_scheduling.main()

    print()
    from benchmarks import roofline_report
    roofline_report.main()


if __name__ == "__main__":
    main()

"""Kernel microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode
(Python), so wall-times are NOT representative of TPU — we benchmark the
jnp reference paths for host-time numbers and assert the kernels agree
with them (correctness microbench). Roofline performance of the kernels
on the v5e target comes from the dry-run analysis, not from here.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit_csv_row

KEY = jax.random.PRNGKey(0)


def timeit(fn, *args, iters=5):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") \
        else jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def bench_wavg():
    from repro.core.averaging import weighted_average
    k, n = 16, 1_000_000
    x = {"p": jax.random.normal(KEY, (k, n))}
    w = jnp.ones(k)
    f = jax.jit(lambda x, w: weighted_average(x, w))
    us = timeit(f, x, w)
    gbps = k * n * 4 / (us / 1e6) / 1e9
    emit_csv_row("wavg_ref_16x1M_f32", us, f"host_GB_s={gbps:.1f}")


def bench_wavg_pallas():
    """The ACTUAL mesh-round hot path: the Pallas `wavg` kernel on a
    flat (K, N) payload — what `weighted_average_psum(impl="pallas")`
    reduces every round after its one all-gather. On this CPU container
    it runs in interpret mode (Python), so the payload is kept modest
    (64 BLOCK_N tiles) and the wall-time is a correctness/regression
    microbench, not a TPU roofline — but BENCH output now tracks the
    code path the mesh engine executes, alongside the jnp reference."""
    from repro.kernels.wavg import ops as wavg_ops
    from repro.kernels.wavg.kernel import BLOCK_N
    k, n = 16, 64 * BLOCK_N
    x = jax.random.normal(KEY, (k, n))
    w = jnp.full((k,), 1.0 / k)
    f = jax.jit(lambda x, w: wavg_ops.weighted_average(x, w))
    # pin correctness against the reference while we're here
    ref = jnp.einsum("k,kn->n", w, x)
    got = f(x, w)
    import numpy as np
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4)
    us = timeit(f, x, w, iters=3)
    gbps = k * n * 4 / (us / 1e6) / 1e9
    emit_csv_row(f"wavg_pallas_16x{64 * BLOCK_N // 1024}k_f32", us,
                 f"host_GB_s={gbps:.2f};interpret=cpu")


def bench_ssd():
    from repro.nn.ssm import ssd_scan_ref
    b, s, h, p, n = 1, 2048, 8, 64, 64
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    f = jax.jit(lambda *a: ssd_scan_ref(*a, chunk=128))
    us = timeit(f, x, dt, A, B, C)
    tok_s = b * s / (us / 1e6)
    emit_csv_row("ssd_scan_ref_2048x8h", us, f"host_tok_s={tok_s:.0f}")


def bench_flash():
    from repro.nn.flash_ref import flash_attention_ref
    bh, s, d = 8, 2048, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (bh, s, d))
    k = jax.random.normal(ks[1], (bh, s, d))
    v = jax.random.normal(ks[2], (bh, s, d))
    pos = jnp.broadcast_to(jnp.arange(s), (bh, s))
    f = jax.jit(lambda q, k, v: flash_attention_ref(
        q, k, v, pos, pos, None, d ** -0.5, True, None, 512, False))
    us = timeit(f, q, k, v)
    emit_csv_row("flash_ref_8x2048x64", us,
                 f"host_GFLOP_s={2 * 2 * bh * s * s * d / (us / 1e6) / 1e9:.1f}")


def bench_protocol_round():
    from repro.configs.base import ProtocolConfig
    from repro.configs.dcgan import DCGANConfig
    from repro.core import protocol
    from repro.models import dcgan
    from repro.models.specs import make_dcgan_spec
    cfg = DCGANConfig(nz=32, ngf=16, ndf=16, nc=1, image_size=32)
    spec = make_dcgan_spec(cfg)
    pcfg = ProtocolConfig(n_devices=10, n_d=2, n_g=2, sample_size=16,
                          server_sample_size=16)
    state = protocol.make_train_state(
        KEY, lambda k: dcgan.gan_init(k, cfg), pcfg, 10)
    data = jax.random.normal(KEY, (10, 32, 32, 32, 1))
    w = jnp.full((10,), 16.0)
    f = jax.jit(lambda s, d, ww: protocol.gan_round(spec, pcfg, s, d, ww,
                                                    KEY))
    us = timeit(f, state, data, w, iters=3)
    emit_csv_row("protocol_round_K10_dcgan32", us,
                 "one_full_communication_round")


def main():
    bench_wavg()
    bench_wavg_pallas()
    bench_ssd()
    bench_flash()
    bench_protocol_round()


if __name__ == "__main__":
    main()

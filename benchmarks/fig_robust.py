"""Robustness figure: FID under hostile workers, with and without the
robust Pallas reducers.

Two sweeps through the shared figure harness (`benchmarks.common`),
both on the fused stacked driver at K=8 workers:

  free-rider sweep — n_free_riders in {0, 2, 4} (0% / 25% / 50% of the
      fleet replaying the stale global model instead of training) x
      reducer in {mean, trimmed_mean, krum}: final FID per cell. The
      plain mean degrades as the free-rider fraction grows; the robust
      reducers hold (the paper's motivating hostile-edge regime).
  honest-majority recovery — 3-of-8 byzantine workers uploading
      10x-scaled Gaussian noise: full FID-vs-round curves for the plain
      mean vs trimmed_mean vs krum, recording whether an honest
      majority recovers convergence once the corrupted uploads are
      down-weighted out of the aggregate.

Every run merges its curves into BENCH_robust.json (the
`driver_bench.write_json` merge pattern: re-running one sweep preserves
the other's entry).

`--smoke` shrinks both sweeps for CI and gates on correctness rather
than FID quality (synthetic data at smoke scale is too noisy to
threshold): (a) every FID in every cell is finite; (b) with ZERO faults
the identity-regime reducers (trimmed_mean trim=0, krum f=0) reproduce
the plain-mean FID — the robust hot path degrades to `wavg` exactly
when asked to tolerate nothing. Exit 2 on violation.

    PYTHONPATH=src python benchmarks/fig_robust.py            # full
    PYTHONPATH=src python benchmarks/fig_robust.py --smoke    # CI gate
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)    # for `benchmarks.common`

from benchmarks.common import ROUNDS, run_experiment, last_fid, emit_csv_row
from repro.core.faults import FaultConfig
from repro.kernels.robust_avg import RobustConfig

K = 8

REDUCERS = {
    "mean": None,
    "trimmed_mean": RobustConfig(method="trimmed_mean", trim=1),
    "krum": RobustConfig(method="krum", krum_f=2),
}


def _faults(**kw):
    return FaultConfig(n_devices=K, **kw) if kw else None


def free_rider_sweep(rounds: int, fractions, reducers) -> dict:
    """final FID per (n_free_riders x reducer) cell."""
    out = {}
    for n_fr in fractions:
        faults = _faults(n_free_riders=n_fr) if n_fr else None
        for name in reducers:
            c = run_experiment(
                f"robust_fr{n_fr}_{name}", k=K, rounds=rounds,
                faults=faults, reducer=REDUCERS[name])
            fid = last_fid(c)
            emit_csv_row(f"fig_robust_fr{n_fr}_{name}", 0.0,
                         f"final_fid={fid:.2f}")
            out[f"fr{n_fr}/{name}"] = {
                "n_free_riders": n_fr, "reducer": name,
                "curve": c.as_dict(), "final_fid": fid}
    return out


def recovery_sweep(rounds: int, reducers) -> dict:
    """honest-majority recovery: 3-of-8 byzantine, curve per reducer."""
    faults = _faults(n_byzantine=3, byz_scale=10.0)
    out = {}
    for name in reducers:
        c = run_experiment(
            f"robust_byz3_{name}", k=K, rounds=rounds,
            faults=faults, reducer=REDUCERS[name])
        fid = last_fid(c)
        emit_csv_row(f"fig_robust_byz3_{name}", 0.0,
                     f"final_fid={fid:.2f}")
        out[f"byz3/{name}"] = {"n_byzantine": 3, "reducer": name,
                               "curve": c.as_dict(), "final_fid": fid}
    return out


def identity_gate(rounds: int):
    """Zero faults: identity-regime reducers must match the plain mean.

    trim=0 / krum f=0 make the robust weight vectors bitwise-identical
    to wavg's, so the FID curves agree to round-off (same kernel, same
    masks). A loose relative tolerance absorbs the float32 flatten
    path's round-off amplified through training + FID."""
    base = run_experiment("robust_identity_mean", k=K, rounds=rounds)
    failures = []
    for name, cfg in (
            ("trimmed_mean", RobustConfig(method="trimmed_mean", trim=0)),
            ("krum", RobustConfig(method="krum", krum_f=0)),
    ):
        c = run_experiment(f"robust_identity_{name}", k=K, rounds=rounds,
                           reducer=cfg)
        ref, got = last_fid(base), last_fid(c)
        tol = max(0.05 * abs(ref), 0.5)
        emit_csv_row(f"fig_robust_identity_{name}", 0.0,
                     f"fid={got:.3f};mean_fid={ref:.3f}")
        if not abs(got - ref) <= tol:
            failures.append(
                f"identity-regime {name} FID {got:.3f} departs from the "
                f"plain mean {ref:.3f} (tol {tol:.3f}) with zero faults")
    return failures


def write_json(path: str, section: str, data: dict):
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload.setdefault("sweeps", {})[section] = data
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI run; exit non-zero if a FID is "
                         "non-finite or the zero-fault identity regimes "
                         "depart from the plain mean")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json", default="BENCH_robust.json")
    args = ap.parse_args(argv)
    rounds = args.rounds or (4 if args.smoke else ROUNDS)

    if args.smoke:
        fractions, reducers = (0, 4), ("mean", "trimmed_mean")
        rec_reducers = ("mean", "krum")
    else:
        fractions, reducers = (0, 2, 4), tuple(REDUCERS)
        rec_reducers = tuple(REDUCERS)

    fr = free_rider_sweep(rounds, fractions, reducers)
    rec = recovery_sweep(rounds, rec_reducers)
    write_json(args.json, "free_riders", fr)
    write_json(args.json, "byz_recovery", rec)

    failures = []
    for label, cell in {**fr, **rec}.items():
        fid = cell["final_fid"]
        if not (fid == fid and abs(fid) != float("inf")):
            failures.append(f"{label}: non-finite final FID {fid}")
    if args.smoke:
        failures += identity_gate(rounds)

    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)
    return 2 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

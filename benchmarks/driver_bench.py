"""Driver microbenchmark: rounds/sec of per-round dispatch vs the fused
multi-round engine, on BOTH execution layouts, at K=8 devices and the
paper-default 16-bit quantized uplink — plus the per-rank
Algorithm-2 all-gather payload at each tensor-parallel width (the
simulated CHANNEL uplink is tp-invariant by design; this column is
the collective payload each TP rank actually gathers).

  --layout stacked (default): the per-round host loop vs the fused
      `protocol.rounds_scan`, for both fused algorithms (proposed +
      FedGAN). Runs on a single device.
  --layout mesh: the per-round shard_map dispatch (host scheduling, one
      XLA dispatch per round) vs the fused in-shard_map scan (R rounds
      inside ONE dispatch) — `shard_round.shard_rounds_scan` for the
      proposed protocol and `shard_round.fedgan_shard_rounds_scan` for
      FedGAN, so BENCH_driver.json records fused-vs-per-round speedup
      for both algorithms on both layouts. Requires >= K addressable
      devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8.
  --tp N (mesh only): run every worker slice as an N-wide Megatron TP
      group on the 2-D (data x model) mesh — the model is
      `models.gan.mlp_gan_spec(tp_axis="model")`, the state enters
      shard_map sharded over `model`, and the recorded
      `allgather_bytes_per_rank` column shrinks by ~1/N (each TP rank
      all-gathers only its parameter shard in Algorithm 2). Requires
      K x N addressable devices (16 for the CI tp=2 smoke).
  --avg-impl ring (mesh only): Algorithm 2 runs as the chunked
      quantized-payload ring collective (kernels/ring_wavg) instead of
      the flat all-gather + Pallas wavg. The run is keyed "mesh_ring"
      in BENCH_driver.json and additionally records a `ring_vs_flat`
      comparison at K=8: fused rounds/sec ring vs flat on the bench
      model (warning-only — the CPU-simulated mesh moves no real
      wire), and the per-rank collective wire bytes at PAPER SCALE
      (the ~661k-param 32x32 DCGAN disc the HLO-cost test lowers,
      where BLOCK padding is noise). The bytes reduction is
      deterministic, so `--smoke` FAILS if the encoded ring wire is
      not <= 0.55x the flat f32 gather at 16 bits.

The fused driver's win is everything per-round dispatch pays — dispatch
latency, weight/metrics host sync, numpy scheduling — so the bench runs
a deliberately tiny MLP-GAN: the round's FLOPs are negligible and both
drivers are measured in the dispatch-bound regime the fused engine
targets (at real model scale the same savings apply per round, they are
just a smaller fraction of the round). Acceptance target: >= 2x
rounds/sec over per-round dispatch for each measured pair.

    PYTHONPATH=src python benchmarks/driver_bench.py              # full
    PYTHONPATH=src python benchmarks/driver_bench.py --smoke      # CI
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python benchmarks/driver_bench.py --smoke --layout mesh
    XLA_FLAGS=--xla_force_host_platform_device_count=16 \
    PYTHONPATH=src python benchmarks/driver_bench.py --smoke --layout mesh --tp 2

Every run merges its rounds/sec + all-gather-bytes numbers into
BENCH_driver.json (keyed per layout, with tp widths > 1 keyed
"mesh_tp<N>"), so CI artifacts record every layout x algorithm x tp
side by side. `--smoke` shrinks the measurement and exits non-zero if a
fused path regresses below per-round dispatch (threshold 1.2x,
conservative against CI-runner noise), so fused-path slowdowns fail in
CI instead of surfacing in benchmark reports.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import ProtocolConfig
from repro.core import Trainer
from repro.core.channel import ChannelConfig
from repro.models.gan import mlp_gan_init, mlp_gan_spec
from repro.sharding import rules

K = int(os.environ.get("REPRO_DRIVER_BENCH_K", "8"))
N_ROUNDS = int(os.environ.get("REPRO_DRIVER_BENCH_ROUNDS", "50"))

# Tiny two-layer MLP-GAN over 64-dim "flattened images": a handful of
# matmuls per round, so round time ~ driver overhead, not model FLOPs.
# Lives in models/gan.py (mlp_gan_*) so the TP equivalence tests pin
# the exact model this bench measures.
NZ, HIDDEN, DIM = 8, 16, 64


def _gan_init(key):
    return mlp_gan_init(key, d_z=NZ, d_hidden=HIDDEN, d_data=DIM)


def make_trainer(driver: str, algorithm: str, layout: str = "stacked",
                 tp: int = 1, avg_impl: str = "pallas") -> Trainer:
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3)
    data = jax.random.normal(jax.random.PRNGKey(9), (K, 8, DIM))
    spec = mlp_gan_spec(d_z=NZ, tp_axis="model" if tp > 1 else None)
    return Trainer(spec, pcfg, _gan_init, data,
                   jax.random.PRNGKey(0), algorithm=algorithm,
                   channel_cfg=ChannelConfig(n_devices=K), driver=driver,
                   layout=layout, tp=tp, avg_impl=avg_impl)


def allgather_bytes_per_rank(algorithm: str, tp: int) -> int:
    """Per-TP-rank Algorithm-2 all-gather payload in bytes (f32): the
    uploaded tree's local shard size — the column the tp sweep is
    about (tp=2 must land at ~1/2 the tp=1 bytes)."""
    state = _gan_init(jax.random.PRNGKey(0))
    payload = (state["disc"] if algorithm == "proposed"
               else {"gen": state["gen"], "disc": state["disc"]})
    return 4 * rules.tp_local_size(payload, tp)


def time_driver(driver: str, algorithm: str, n_rounds: int,
                layout: str = "stacked", tp: int = 1,
                avg_impl: str = "pallas", repeats: int = 3) -> float:
    """rounds/sec: best of `repeats` timed runs of n_rounds after a
    warmup run, so the jitted round (host) / chunk (fused) is already
    compiled and scheduler noise on shared machines is suppressed."""
    trainer = make_trainer(driver, algorithm, layout, tp, avg_impl)
    trainer.run(n_rounds)                       # warmup incl. compile
    jax.block_until_ready(trainer.state)
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        trainer.run(n_rounds)
        jax.block_until_ready(trainer.state)
        best = max(best, n_rounds / (time.perf_counter() - t0))
    return best


def bench_pair(algorithm: str, n_rounds: int, layout: str,
               tp: int = 1, avg_impl: str = "pallas") -> dict:
    """host (per-round dispatch) vs fused, on one layout x tp x impl."""
    host_rps = time_driver("host", algorithm, n_rounds, layout, tp,
                           avg_impl)
    fused_rps = time_driver("fused", algorithm, n_rounds, layout, tp,
                            avg_impl)
    speedup = fused_rps / host_rps
    up_bytes = allgather_bytes_per_rank(algorithm, tp)
    tag = f"driver_bench_{layout_key(layout, tp, avg_impl)}_{algorithm}"
    print(f"{tag}_host,{1e6 / host_rps:.1f},rounds_per_s={host_rps:.1f}")
    print(f"{tag}_fused,{1e6 / fused_rps:.1f},"
          f"rounds_per_s={fused_rps:.1f};speedup={speedup:.2f}x;"
          f"allgather_bytes_per_rank={up_bytes}")
    return {"per_round_rps": host_rps, "fused_rps": fused_rps,
            "speedup": speedup, "allgather_bytes_per_rank": up_bytes}


def layout_key(layout: str, tp: int, avg_impl: str = "pallas") -> str:
    key = layout if tp <= 1 else f"{layout}_tp{tp}"
    return key if avg_impl == "pallas" else f"{key}_{avg_impl}"


def paper_scale_wire_bytes(bits: int = 16) -> dict:
    """Deterministic per-rank collective bytes at PAPER SCALE: the
    ~661k-param 32x32 DCGAN disc (the exact payload
    tests/test_hlo_costs.py lowers and verifies these formulas against
    the optimized HLO, byte for byte). flat = K * N * 4 (the payload is
    dequantized to f32 BEFORE the all-gather); ring = the encoded wire
    (`ring_wire_bytes_per_rank`)."""
    from repro.configs.dcgan import DCGANConfig
    from repro.kernels.ring_wavg.ops import ring_wire_bytes_per_rank
    from repro.models import dcgan as dcgan_mod

    cfg = DCGANConfig(nz=16, ngf=16, ndf=64, nc=1, image_size=32)
    disc = dcgan_mod.gan_init(jax.random.PRNGKey(0), cfg)["disc"]
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(disc))
    flat = K * n * 4
    ring = ring_wire_bytes_per_rank(disc, bits, K)
    return {"payload_params": n, "bits": bits, "flat_bytes": flat,
            "ring_bytes": ring, "bytes_ratio": ring / flat}


def ring_vs_flat(n_rounds: int) -> dict:
    """The --avg-impl ring extra: fused rounds/sec ring vs flat on the
    bench model (K=8 mesh), plus the paper-scale wire-byte comparison.
    Wallclock is informational on a CPU-simulated mesh (no real wire
    to save); the bytes ratio is the deterministic gate."""
    flat_rps = time_driver("fused", "proposed", n_rounds, "mesh",
                           avg_impl="pallas")
    ring_rps = time_driver("fused", "proposed", n_rounds, "mesh",
                           avg_impl="ring")
    out = {"fused_rps_flat": flat_rps, "fused_rps_ring": ring_rps,
           "ring_over_flat_rps": ring_rps / flat_rps,
           "wire": paper_scale_wire_bytes()}
    print(f"driver_bench_ring_vs_flat,rps_ring={ring_rps:.1f};"
          f"rps_flat={flat_rps:.1f};"
          f"ratio={out['ring_over_flat_rps']:.2f}x;"
          f"wire_bytes_ratio={out['wire']['bytes_ratio']:.3f}")
    return out


def write_json(path: str, layout: str, tp: int, results: dict,
               n_rounds: int, avg_impl: str = "pallas",
               ring_cmp: dict | None = None):
    """Merge this layout x tp x impl's numbers into BENCH_driver.json,
    preserving every other entry (and its own measurement length)."""
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    entry = {"k": K, "tp": tp, "rounds": n_rounds, "algorithms": results}
    if avg_impl != "pallas":
        entry["avg_impl"] = avg_impl
    payload.setdefault("layouts", {})[
        layout_key(layout, tp, avg_impl)] = entry
    if ring_cmp is not None:
        payload["ring_vs_flat"] = ring_cmp
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI run; exit non-zero on fused-path "
                         "regression below 1.2x")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--layout", choices=["stacked", "mesh"],
                    default="stacked")
    ap.add_argument("--tp", type=int, default=1,
                    help="mesh only: TP width per worker slice; needs "
                         "K x tp addressable devices")
    ap.add_argument("--avg-impl", choices=["flat", "ring"],
                    default="flat",
                    help="mesh only: Algorithm-2 collective — 'flat' "
                         "(all-gather + Pallas wavg) or 'ring' (chunked "
                         "quantized-payload ring); 'ring' also records "
                         "the ring_vs_flat comparison")
    ap.add_argument("--json", default="BENCH_driver.json",
                    help="merge rounds/sec per layout x tp into this "
                         "file")
    args = ap.parse_args(argv)
    n_rounds = args.rounds or (20 if args.smoke else N_ROUNDS)
    if args.tp > 1 and args.layout != "mesh":
        ap.error("--tp requires --layout mesh")
    if args.avg_impl == "ring" and (args.layout != "mesh" or args.tp > 1):
        ap.error("--avg-impl ring requires --layout mesh --tp 1")
    avg_impl = "pallas" if args.avg_impl == "flat" else "ring"

    if args.layout == "mesh":
        from repro.launch.mesh import devices_error
        err = devices_error(K * args.tp,
                            context=f"--layout mesh --tp {args.tp}")
        if err:
            print(f"FAIL: {err}", file=sys.stderr)
            return 2
    algorithms = ("proposed", "fedgan")   # both layouts run both

    results = {alg: bench_pair(alg, n_rounds, args.layout, args.tp,
                               avg_impl)
               for alg in algorithms}
    ring_cmp = ring_vs_flat(n_rounds) if avg_impl == "ring" else None
    write_json(args.json, args.layout, args.tp, results, n_rounds,
               avg_impl, ring_cmp)

    status = 0
    for alg, r in results.items():
        s = r["speedup"]
        lk = layout_key(args.layout, args.tp, avg_impl)
        if args.smoke and s < 1.2:
            print(f"FAIL: {lk}/{alg} fused speedup {s:.2f}x "
                  f"below the 1.2x smoke threshold", file=sys.stderr)
            status = 2
        elif s < 2.0:
            print(f"WARNING: {lk}/{alg} fused speedup {s:.2f}x "
                  f"below the 2x target", file=sys.stderr)
    if ring_cmp is not None:
        ratio = ring_cmp["wire"]["bytes_ratio"]
        if ratio > 0.55:     # deterministic: fail even outside --smoke
            print(f"FAIL: ring wire bytes ratio {ratio:.3f} above the "
                  f"0.55 contract at 16 bits", file=sys.stderr)
            status = 2
        if ring_cmp["ring_over_flat_rps"] < 1.0:
            print(f"WARNING: fused ring "
                  f"{ring_cmp['ring_over_flat_rps']:.2f}x flat "
                  f"rounds/sec (informational: the CPU-simulated mesh "
                  f"moves no real wire)", file=sys.stderr)
    return status


if __name__ == "__main__":
    sys.exit(main())

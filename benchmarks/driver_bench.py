"""Driver microbenchmark: rounds/sec of the per-round host loop vs the
fused multi-round `gan_rounds_scan` driver, at DCGAN-test scale
(K=8 devices, 50 communication rounds per measurement).

The fused driver's win is everything the host loop pays per round —
dispatch latency, weight/metrics host sync, numpy scheduling — which at
small model scale dominates the round's FLOPs. Acceptance target:
>= 2x rounds/sec over the host loop on CPU.

    PYTHONPATH=src python benchmarks/driver_bench.py
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.configs.dcgan import DCGANConfig
from repro.core import Trainer
from repro.core.channel import ChannelConfig
from repro.models import dcgan
from repro.models.specs import make_dcgan_spec

K = int(os.environ.get("REPRO_DRIVER_BENCH_K", "8"))
N_ROUNDS = int(os.environ.get("REPRO_DRIVER_BENCH_ROUNDS", "50"))


def make_trainer(driver: str) -> Trainer:
    # The dispatch-bound regime the fused driver targets: a test-scale
    # DCGAN (8x8, two conv stages) whose per-round FLOPs are comparable
    # to the host loop's per-round overhead.
    cfg = DCGANConfig(nz=8, ngf=8, ndf=8, nc=1, image_size=8)
    spec = make_dcgan_spec(cfg)
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3)
    data = jax.random.normal(jax.random.PRNGKey(9), (K, 8, 8, 8, 1))
    return Trainer(spec, pcfg, lambda k: dcgan.gan_init(k, cfg), data,
                   jax.random.PRNGKey(0),
                   channel_cfg=ChannelConfig(n_devices=K), driver=driver)


def time_driver(driver: str) -> float:
    """rounds/sec, measured on a second run of N_ROUNDS so the jitted
    round (host) / chunk (fused) is already compiled."""
    trainer = make_trainer(driver)
    trainer.run(N_ROUNDS)                       # warmup incl. compile
    jax.block_until_ready(trainer.state)
    t0 = time.perf_counter()
    trainer.run(N_ROUNDS)
    jax.block_until_ready(trainer.state)
    dt = time.perf_counter() - t0
    return N_ROUNDS / dt


def main():
    host_rps = time_driver("host")
    fused_rps = time_driver("fused")
    speedup = fused_rps / host_rps
    print(f"driver_bench_host,{1e6 / host_rps:.1f},"
          f"rounds_per_s={host_rps:.1f}")
    print(f"driver_bench_fused,{1e6 / fused_rps:.1f},"
          f"rounds_per_s={fused_rps:.1f};speedup={speedup:.2f}x")
    return speedup


if __name__ == "__main__":
    s = main()
    if s < 2.0:
        print(f"WARNING: fused speedup {s:.2f}x below the 2x target",
              file=sys.stderr)

"""Driver microbenchmark: rounds/sec of per-round dispatch vs the fused
multi-round engine, on BOTH execution layouts, at K=8 devices and the
paper-default 16-bit quantized uplink.

  --layout stacked (default): the per-round host loop vs the fused
      `protocol.rounds_scan`, for both fused algorithms (proposed +
      FedGAN). Runs on a single device.
  --layout mesh: the per-round shard_map dispatch (host scheduling, one
      XLA dispatch per round) vs the fused in-shard_map scan (R rounds
      inside ONE dispatch) — `shard_round.shard_rounds_scan` for the
      proposed protocol and `shard_round.fedgan_shard_rounds_scan` for
      FedGAN, so BENCH_driver.json records fused-vs-per-round speedup
      for both algorithms on both layouts. Requires >= K addressable
      devices, e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8.

The fused driver's win is everything per-round dispatch pays — dispatch
latency, weight/metrics host sync, numpy scheduling — so the bench runs
a deliberately tiny MLP-GAN: the round's FLOPs are negligible and both
drivers are measured in the dispatch-bound regime the fused engine
targets (at real model scale the same savings apply per round, they are
just a smaller fraction of the round). Acceptance target: >= 2x
rounds/sec over per-round dispatch for each measured pair.

    PYTHONPATH=src python benchmarks/driver_bench.py              # full
    PYTHONPATH=src python benchmarks/driver_bench.py --smoke      # CI
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python benchmarks/driver_bench.py --smoke --layout mesh

Every run merges its rounds/sec numbers into BENCH_driver.json (keyed
per layout), so CI artifacts record both layouts side by side.
`--smoke` shrinks the measurement and exits non-zero if a fused path
regresses below per-round dispatch (threshold 1.2x, conservative
against CI-runner noise), so fused-path slowdowns fail in CI instead of
surfacing in benchmark reports.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ProtocolConfig
from repro.core import Trainer
from repro.core.channel import ChannelConfig
from repro.core.protocol import GanModelSpec

K = int(os.environ.get("REPRO_DRIVER_BENCH_K", "8"))
N_ROUNDS = int(os.environ.get("REPRO_DRIVER_BENCH_ROUNDS", "50"))

# Tiny two-layer MLP-GAN over 64-dim "flattened images": a handful of
# matmuls per round, so round time ~ driver overhead, not model FLOPs.
NZ, HIDDEN, DIM = 8, 16, 64


def _gan_init(key):
    ks = jax.random.split(key, 4)
    s = lambda k, sh: jax.random.normal(k, sh) * 0.1
    return {"gen": {"w1": s(ks[0], (NZ, HIDDEN)),
                    "w2": s(ks[1], (HIDDEN, DIM))},
            "disc": {"w1": s(ks[2], (DIM, HIDDEN)),
                     "w2": s(ks[3], (HIDDEN, 1))}}


def _disc_logits(p, x):
    return (jnp.tanh(x.reshape(x.shape[0], -1) @ p["w1"]) @ p["w2"])[:, 0]


BENCH_SPEC = GanModelSpec(
    sample_z=lambda k, n: jax.random.normal(k, (n, NZ)),
    gen_apply=lambda p, z: jnp.tanh(jnp.tanh(z @ p["w1"]) @ p["w2"]),
    disc_real=_disc_logits,
    disc_fake=_disc_logits)


def make_trainer(driver: str, algorithm: str,
                 layout: str = "stacked") -> Trainer:
    pcfg = ProtocolConfig(n_devices=K, n_d=1, n_g=1, sample_size=4,
                          server_sample_size=4, lr_d=1e-3, lr_g=1e-3)
    data = jax.random.normal(jax.random.PRNGKey(9), (K, 8, DIM))
    return Trainer(BENCH_SPEC, pcfg, _gan_init, data,
                   jax.random.PRNGKey(0), algorithm=algorithm,
                   channel_cfg=ChannelConfig(n_devices=K), driver=driver,
                   layout=layout)


def time_driver(driver: str, algorithm: str, n_rounds: int,
                layout: str = "stacked", repeats: int = 3) -> float:
    """rounds/sec: best of `repeats` timed runs of n_rounds after a
    warmup run, so the jitted round (host) / chunk (fused) is already
    compiled and scheduler noise on shared machines is suppressed."""
    trainer = make_trainer(driver, algorithm, layout)
    trainer.run(n_rounds)                       # warmup incl. compile
    jax.block_until_ready(trainer.state)
    best = 0.0
    for _ in range(repeats):
        t0 = time.perf_counter()
        trainer.run(n_rounds)
        jax.block_until_ready(trainer.state)
        best = max(best, n_rounds / (time.perf_counter() - t0))
    return best


def bench_pair(algorithm: str, n_rounds: int, layout: str) -> dict:
    """host (per-round dispatch) vs fused, on one layout."""
    host_rps = time_driver("host", algorithm, n_rounds, layout)
    fused_rps = time_driver("fused", algorithm, n_rounds, layout)
    speedup = fused_rps / host_rps
    tag = f"driver_bench_{layout}_{algorithm}"
    print(f"{tag}_host,{1e6 / host_rps:.1f},rounds_per_s={host_rps:.1f}")
    print(f"{tag}_fused,{1e6 / fused_rps:.1f},"
          f"rounds_per_s={fused_rps:.1f};speedup={speedup:.2f}x")
    return {"per_round_rps": host_rps, "fused_rps": fused_rps,
            "speedup": speedup}


def write_json(path: str, layout: str, results: dict, n_rounds: int):
    """Merge this layout's numbers into BENCH_driver.json, preserving
    the other layout's entry (and its own measurement length)."""
    payload = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            payload = {}
    payload.setdefault("layouts", {})[layout] = {
        "k": K, "rounds": n_rounds, "algorithms": results}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI run; exit non-zero on fused-path "
                         "regression below 1.2x")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--layout", choices=["stacked", "mesh"],
                    default="stacked")
    ap.add_argument("--json", default="BENCH_driver.json",
                    help="merge rounds/sec per layout into this file")
    args = ap.parse_args(argv)
    n_rounds = args.rounds or (20 if args.smoke else N_ROUNDS)

    if args.layout == "mesh":
        from repro.launch.mesh import devices_error
        err = devices_error(K)
        if err:
            print(f"FAIL: {err}", file=sys.stderr)
            return 2
    algorithms = ("proposed", "fedgan")   # both layouts run both

    results = {alg: bench_pair(alg, n_rounds, args.layout)
               for alg in algorithms}
    write_json(args.json, args.layout, results, n_rounds)

    status = 0
    for alg, r in results.items():
        s = r["speedup"]
        if args.smoke and s < 1.2:
            print(f"FAIL: {args.layout}/{alg} fused speedup {s:.2f}x "
                  f"below the 1.2x smoke threshold", file=sys.stderr)
            status = 2
        elif s < 2.0:
            print(f"WARNING: {args.layout}/{alg} fused speedup {s:.2f}x "
                  f"below the 2x target", file=sys.stderr)
    return status


if __name__ == "__main__":
    sys.exit(main())

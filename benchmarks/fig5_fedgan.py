"""Fig. 5 — comparison with FedGAN [9]. Paper claims: proposed-serial
converges faster in wall-clock than FedGAN (half the upload bytes, half
the device compute); proposed-parallel ~ FedGAN.

Both algorithms run the FUSED driver (PR 2: FedGAN shares the unified
`rounds_scan` engine) with the paper's 16-bit quantized uplink
exercised per round; the trailing rows ablate the uplink bit width,
which shrinks simulated upload time for both algorithms.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import run_experiment, last_fid, emit_csv_row


def main(out_dir="results/bench"):
    os.makedirs(out_dir, exist_ok=True)
    curves = []
    settings = [("proposed-serial", "proposed", "serial", 16),
                ("proposed-parallel", "proposed", "parallel", 16),
                ("fedgan", "fedgan", "serial", 16),
                ("proposed-serial-8bit", "proposed", "serial", 8),
                ("fedgan-8bit", "fedgan", "serial", 8)]
    for label, algorithm, schedule, bits in settings:
        t0 = time.time()
        c = run_experiment(f"fig5/{label}", dataset="celeba",
                           algorithm=algorithm, schedule=schedule,
                           bits=bits)
        dt = (time.time() - t0) * 1e6 / max(len(c.rounds), 1)
        curves.append(c)
        emit_csv_row(f"fig5_{label}", dt,
                     f"final_fid={last_fid(c):.2f};"
                     f"wallclock={c.wallclock[-1]:.1f}s")
    with open(os.path.join(out_dir, "fig5_fedgan.json"), "w") as f:
        json.dump([c.as_dict() for c in curves], f, indent=2)
    return curves


if __name__ == "__main__":
    main()

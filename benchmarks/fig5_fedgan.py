"""Fig. 5 — comparison with FedGAN [9]. Paper claims: proposed-serial
converges faster in wall-clock than FedGAN (half the upload bytes, half
the device compute); proposed-parallel ~ FedGAN."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import run_experiment, last_fid, emit_csv_row


def main(out_dir="results/bench"):
    os.makedirs(out_dir, exist_ok=True)
    curves = []
    settings = [("proposed-serial", "proposed", "serial"),
                ("proposed-parallel", "proposed", "parallel"),
                ("fedgan", "fedgan", "serial")]
    for label, algorithm, schedule in settings:
        t0 = time.time()
        c = run_experiment(f"fig5/{label}", dataset="celeba",
                           algorithm=algorithm, schedule=schedule)
        dt = (time.time() - t0) * 1e6 / max(len(c.rounds), 1)
        curves.append(c)
        emit_csv_row(f"fig5_{label}", dt,
                     f"final_fid={last_fid(c):.2f};"
                     f"wallclock={c.wallclock[-1]:.1f}s")
    with open(os.path.join(out_dir, "fig5_fedgan.json"), "w") as f:
        json.dump([c.as_dict() for c in curves], f, indent=2)
    return curves


if __name__ == "__main__":
    main()

"""Fig. 5 — comparison with FedGAN [9]. Paper claims: proposed-serial
converges faster in wall-clock than FedGAN (half the upload bytes, half
the device compute); proposed-parallel ~ FedGAN.

Both algorithms run the FUSED driver (PR 2: FedGAN shares the unified
`rounds_scan` engine) with the paper's 16-bit quantized uplink
exercised per round; the trailing rows ablate the uplink bit width,
which shrinks simulated upload time for both algorithms.

--layout selects the execution layout for EVERY setting (no silent
stacked assumption): layout="mesh" runs both algorithms through the
fused shard_map engine (`shard_round.shard_rounds_scan` /
`fedgan_shard_rounds_scan`) and needs >= K addressable devices, e.g.
XLA_FLAGS=--xla_force_host_platform_device_count=8 with --devices 8.
--smoke shrinks to one proposed + one FedGAN setting (CI smoke; round
count still via REPRO_BENCH_ROUNDS).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

from benchmarks.common import run_experiment, last_fid, emit_csv_row

SETTINGS = [("proposed-serial", "proposed", "serial", 16),
            ("proposed-parallel", "proposed", "parallel", 16),
            ("fedgan", "fedgan", "serial", 16),
            ("proposed-serial-8bit", "proposed", "serial", 8),
            ("fedgan-8bit", "fedgan", "serial", 8)]


def main(out_dir="results/bench", layout="stacked", k=10, smoke=False):
    os.makedirs(out_dir, exist_ok=True)
    curves = []
    settings = SETTINGS
    if smoke:   # one setting per algorithm keeps CI smoke cheap
        settings = [SETTINGS[0], SETTINGS[2]]
    for label, algorithm, schedule, bits in settings:
        t0 = time.time()
        c = run_experiment(f"fig5/{label}", dataset="celeba",
                           algorithm=algorithm, schedule=schedule,
                           bits=bits, layout=layout, k=k)
        dt = (time.time() - t0) * 1e6 / max(len(c.rounds), 1)
        curves.append(c)
        emit_csv_row(f"fig5_{label}_{layout}", dt,
                     f"final_fid={last_fid(c):.2f};"
                     f"wallclock={c.wallclock[-1]:.1f}s")
    with open(os.path.join(out_dir, f"fig5_fedgan_{layout}.json"),
              "w") as f:
        json.dump([c.as_dict() for c in curves], f, indent=2)
    return curves


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="results/bench")
    ap.add_argument("--layout", choices=["stacked", "mesh"],
                    default="stacked",
                    help="execution layout for every setting (mesh "
                         "needs >= --devices addressable devices)")
    ap.add_argument("--devices", type=int, default=10,
                    help="fleet size K (the paper's 10)")
    ap.add_argument("--smoke", action="store_true",
                    help="one proposed + one FedGAN setting only")
    args = ap.parse_args()
    if args.layout == "mesh":
        from repro.launch.mesh import devices_error
        err = devices_error(args.devices)
        if err:
            sys.exit(err)
    main(args.out_dir, layout=args.layout, k=args.devices,
         smoke=args.smoke)

"""Fig. 3 — learning performance of the two update schedules on the
three datasets. Paper claims: (i) both converge; (ii) serial needs fewer
rounds and less wall-clock than parallel under limited bandwidth."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import run_experiment, last_fid, emit_csv_row


def main(out_dir="results/bench", driver=None):
    # driver=None falls through to run_experiment's REPRO_BENCH_DRIVER default
    os.makedirs(out_dir, exist_ok=True)
    curves = []
    for dataset in ("celeba", "cifar10", "rsna"):
        for schedule in ("serial", "parallel"):
            t0 = time.time()
            c = run_experiment(f"{dataset}/{schedule}", dataset=dataset,
                               schedule=schedule, driver=driver)
            dt = (time.time() - t0) * 1e6 / max(len(c.rounds), 1)
            curves.append(c)
            emit_csv_row(f"fig3_{dataset}_{schedule}", dt,
                         f"final_fid={last_fid(c):.2f};"
                         f"wallclock={c.wallclock[-1]:.1f}s")
    with open(os.path.join(out_dir, "fig3_schedules.json"), "w") as f:
        json.dump([c.as_dict() for c in curves], f, indent=2)
    return curves


if __name__ == "__main__":
    main()
